/* Native inner engine for the ECB forest builder (ecb_forest.py).
 *
 * This is a line-for-line port of FastIncrementalBuilder's run loop:
 * descending start times, per-ts candidate batch in ascending rank,
 * findInsertion (incidence bisect + parent climb), the zipper merge of
 * the two ancestor chains with LCA expiry, and the per-ts delta flush.
 * No MSF prefilter: insert's own cycle check (l == r) rejects non-MSF
 * candidates, and a rejected attempt costs two bisects + climbs here,
 * not a Python frame. Entry order within one ts differs from the Python
 * builders (insertion order vs set order) but pack_index canonicalizes
 * by (id, ts), so packed indices are bit-identical — tests assert this.
 *
 * Compiled on demand by ecb_native.py with the host cc; if that fails
 * the Python builders serve identically (slower).
 *
 * Return codes: 0 ok; 1 entry buffers too small (true counts in out,
 * caller re-runs with larger buffers); 2 forest invariant violated;
 * 3 out of memory.
 */

#include <stdint.h>
#include <stdlib.h>
#include <string.h>

#define NONE (-1)

typedef struct {
    int64_t *key;   /* packed ranks, ascending */
    int32_t *node;
    int32_t len, cap;
} Inc;

static int inc_bisect(const Inc *inc, int64_t key) {
    int lo = 0, hi = inc->len;
    while (lo < hi) {
        int mid = (lo + hi) >> 1;
        if (inc->key[mid] < key) lo = mid + 1; else hi = mid;
    }
    return lo;
}

static int inc_add(Inc *inc, int64_t key, int32_t node) {
    if (inc->len == inc->cap) {
        int ncap = inc->cap ? inc->cap * 2 : 4;
        int64_t *nk = (int64_t *)realloc(inc->key, (size_t)ncap * sizeof(int64_t));
        if (!nk) return 3;
        inc->key = nk;
        int32_t *nn = (int32_t *)realloc(inc->node, (size_t)ncap * sizeof(int32_t));
        if (!nn) return 3;
        inc->node = nn;
        inc->cap = ncap;
    }
    int i = inc_bisect(inc, key);
    memmove(inc->key + i + 1, inc->key + i,
            (size_t)(inc->len - i) * sizeof(int64_t));
    memmove(inc->node + i + 1, inc->node + i,
            (size_t)(inc->len - i) * sizeof(int32_t));
    inc->key[i] = key;
    inc->node[i] = node;
    inc->len++;
    return 0;
}

static int inc_remove(Inc *inc, int64_t key, int32_t node) {
    int i = inc_bisect(inc, key);
    if (i >= inc->len || inc->node[i] != node) return 2;
    memmove(inc->key + i, inc->key + i + 1,
            (size_t)(inc->len - i - 1) * sizeof(int64_t));
    memmove(inc->node + i, inc->node + i + 1,
            (size_t)(inc->len - i - 1) * sizeof(int32_t));
    inc->len--;
    return 0;
}

typedef struct {
    Inc *inc;                       /* per graph vertex */
    int32_t *n_parent, *n_child0, *n_child1;
    int64_t *n_rank;
    const int32_t *n_u;
    uint8_t *n_in;
    /* dirty node / vertex tracking: stamp + insertion-order list */
    uint8_t *dn_stamp, *dv_stamp;
    int32_t *dn_list, *dv_list;
    int64_t dn_len, dv_len;
} State;

#define DIRTY_NODE(st, x) do { \
    if (!(st)->dn_stamp[x]) { (st)->dn_stamp[x] = 1; \
        (st)->dn_list[(st)->dn_len++] = (x); } } while (0)
#define DIRTY_VERT(st, x) do { \
    if (!(st)->dv_stamp[x]) { (st)->dv_stamp[x] = 1; \
        (st)->dv_list[(st)->dv_len++] = (x); } } while (0)

/* findInsertion for one endpoint: component maximum below rk, its old
 * parent (the lowest incident node above rk), and the consumed slot. */
static int find_side(State *st, int32_t vert, int64_t rk,
                     int32_t *child, int32_t *attach, int *via) {
    Inc *inc = &st->inc[vert];
    int i = inc_bisect(inc, rk);
    if (i > 0) {
        int32_t ch = inc->node[i - 1];
        const int32_t *parent = st->n_parent;
        const int64_t *rank = st->n_rank;
        int32_t p = parent[ch];
        while (p != NONE && rank[p] < rk) {
            ch = p;
            p = parent[ch];
        }
        *child = ch;
        *attach = p;
        if (p == NONE) { *via = NONE; return 0; }
        if (st->n_child0[p] == ch) *via = 0;
        else if (st->n_child1[p] == ch) *via = 1;
        else return 2;
        return 0;
    }
    if (i >= inc->len) {
        *child = NONE; *attach = NONE; *via = NONE;
        return 0;
    }
    int32_t at = inc->node[i];
    int v = (st->n_u[at] == vert) ? 0 : 1;
    int32_t taken = v == 0 ? st->n_child0[at] : st->n_child1[at];
    if (taken != NONE) return 2;
    *child = NONE; *attach = at; *via = v;
    return 0;
}

int ecb_run(
    int32_t n, int32_t t_max, int64_t stride, int64_t R,
    const int32_t *esrc, const int32_t *edst,
    const int64_t *e_sorted, const int64_t *c_sorted, const int64_t *neg_ts,
    int32_t *n_edge, int32_t *n_ct, int32_t *n_u, int32_t *n_v,
    int64_t *n_rank, int32_t *n_live_from, int32_t *n_live_to,
    int32_t *n_parent, int32_t *n_child0, int32_t *n_child1, uint8_t *n_in,
    int64_t ent_cap, int32_t *ent_node, int32_t *ent_ts,
    int32_t *ent_l, int32_t *ent_r, int32_t *ent_p,
    int64_t vent_cap, int32_t *vent_vert, int32_t *vent_ts,
    int32_t *vent_node,
    int64_t *out)
{
    int rc = 0;
    int64_t num_nodes = 0, ent_len = 0, vent_len = 0;
    int64_t i;

    Inc *inc = (Inc *)calloc((size_t)n ? (size_t)n : 1, sizeof(Inc));
    uint8_t *dn_stamp = (uint8_t *)calloc((size_t)R ? (size_t)R : 1, 1);
    uint8_t *dv_stamp = (uint8_t *)calloc((size_t)n ? (size_t)n : 1, 1);
    int32_t *dn_list = (int32_t *)malloc(((size_t)R ? (size_t)R : 1)
                                         * sizeof(int32_t));
    int32_t *dv_list = (int32_t *)malloc(((size_t)n ? (size_t)n : 1)
                                         * sizeof(int32_t));
    /* last recorded (l, r, p) per node / entry node per vertex;
     * -2 = never recorded (NONE = -1 is a legal value) */
    int32_t *last3 = (int32_t *)malloc(((size_t)(3 * R) ? (size_t)(3 * R) : 1)
                                       * sizeof(int32_t));
    int32_t *last_vent = (int32_t *)malloc(((size_t)n ? (size_t)n : 1)
                                           * sizeof(int32_t));
    if (!inc || !dn_stamp || !dv_stamp || !dn_list || !dv_list
            || !last3 || !last_vent) { rc = 3; goto done; }
    for (i = 0; i < 3 * R; i++) last3[i] = -2;
    for (i = 0; i < n; i++) last_vent[i] = -2;

    State st;
    st.inc = inc;
    st.n_parent = n_parent; st.n_child0 = n_child0; st.n_child1 = n_child1;
    st.n_rank = n_rank; st.n_u = n_u; st.n_in = n_in;
    st.dn_stamp = dn_stamp; st.dv_stamp = dv_stamp;
    st.dn_list = dn_list; st.dv_list = dv_list;
    st.dn_len = 0; st.dv_len = 0;

    int64_t pos = 0;  /* neg_ts ascending = ts descending: one sweep */
    int32_t ts;
    for (ts = t_max; ts >= 1; ts--) {
        while (pos < R && neg_ts[pos] == -(int64_t)ts) {
            int64_t e = e_sorted[pos];
            int64_t c = c_sorted[pos];
            pos++;
            int32_t uu = esrc[e], vv = edst[e];
            if (uu == vv) continue;   /* degenerate self-loop */
            int64_t rk = c * stride + e;
            int32_t l, eu, r, ev;
            int va, vb;
            rc = find_side(&st, uu, rk, &l, &eu, &va);
            if (rc) goto done;
            rc = find_side(&st, vv, rk, &r, &ev, &vb);
            if (rc) goto done;
            if (l != NONE && l == r) continue;   /* cycle: not in MSF */

            if (num_nodes >= R) { rc = 2; goto done; }
            int32_t x = (int32_t)num_nodes++;
            n_edge[x] = (int32_t)e;
            n_ct[x] = (int32_t)c;
            n_u[x] = uu;
            n_v[x] = vv;
            n_rank[x] = rk;
            n_live_from[x] = 1;
            n_live_to[x] = ts;
            n_parent[x] = NONE;
            n_in[x] = 1;
            n_child0[x] = l;
            n_child1[x] = r;
            if (l != NONE) { n_parent[l] = x; DIRTY_NODE(&st, l); }
            if (r != NONE) { n_parent[r] = x; DIRTY_NODE(&st, r); }
            rc = inc_add(&inc[uu], rk, x);
            if (rc) goto done;
            rc = inc_add(&inc[vv], rk, x);
            if (rc) goto done;
            DIRTY_VERT(&st, uu);
            DIRTY_VERT(&st, vv);
            DIRTY_NODE(&st, x);

            /* zipper merge of the two ancestor chains (WE cascade);
             * (a, va) and (b, vb) are the chain heads and the slot each
             * hands to the node hung beneath it */
            int32_t cur = x, a = eu, b = ev;
            for (;;) {
                if (a == NONE && b == NONE) { n_parent[cur] = NONE; break; }
                if (a == NONE || b == NONE) {
                    int32_t t; int s;
                    if (a != NONE) { t = a; s = va; } else { t = b; s = vb; }
                    n_parent[cur] = t;
                    if (s == 0) n_child0[t] = cur; else n_child1[t] = cur;
                    DIRTY_NODE(&st, t);
                    break;
                }
                if (a == b) {
                    /* Lemma 5.7: the meeting node is the LCA -> expired */
                    int32_t p = n_parent[a];
                    n_parent[cur] = p;
                    if (p != NONE) {
                        if (n_child0[p] == a) n_child0[p] = cur;
                        else if (n_child1[p] == a) n_child1[p] = cur;
                        else { rc = 2; goto done; }
                        DIRTY_NODE(&st, p);
                    }
                    n_in[a] = 0;
                    n_live_from[a] = ts + 1;
                    rc = inc_remove(&inc[n_u[a]], n_rank[a], a);
                    if (rc) goto done;
                    rc = inc_remove(&inc[n_v[a]], n_rank[a], a);
                    if (rc) goto done;
                    DIRTY_VERT(&st, n_u[a]);
                    DIRTY_VERT(&st, n_v[a]);
                    break;
                }
                int32_t lo; int vlo;
                if (n_rank[a] < n_rank[b]) { lo = a; vlo = va; }
                else { lo = b; vlo = vb; b = a; vb = va; }
                int32_t nxt = n_parent[lo];
                n_parent[cur] = lo;
                if (vlo == 0) n_child0[lo] = cur; else n_child1[lo] = cur;
                DIRTY_NODE(&st, lo);
                if (nxt != NONE) {
                    if (n_child0[nxt] == lo) va = 0;
                    else if (n_child1[nxt] == lo) va = 1;
                    else { rc = 2; goto done; }
                }
                cur = lo; a = nxt;
            }
        }

        /* per-ts delta flush */
        for (i = 0; i < st.dn_len; i++) {
            int32_t x = st.dn_list[i];
            st.dn_stamp[x] = 0;
            if (!n_in[x]) continue;
            int32_t l = n_child0[x], r = n_child1[x], p = n_parent[x];
            int32_t *lx = last3 + 3 * (int64_t)x;
            if (lx[0] != l || lx[1] != r || lx[2] != p) {
                lx[0] = l; lx[1] = r; lx[2] = p;
                if (ent_len < ent_cap) {
                    ent_node[ent_len] = x;
                    ent_ts[ent_len] = ts;
                    ent_l[ent_len] = l;
                    ent_r[ent_len] = r;
                    ent_p[ent_len] = p;
                }
                ent_len++;
            }
        }
        st.dn_len = 0;
        for (i = 0; i < st.dv_len; i++) {
            int32_t vert = st.dv_list[i];
            st.dv_stamp[vert] = 0;
            int32_t node = inc[vert].len ? inc[vert].node[0] : NONE;
            if (last_vent[vert] != node) {
                last_vent[vert] = node;
                if (vent_len < vent_cap) {
                    vent_vert[vent_len] = vert;
                    vent_ts[vent_len] = ts;
                    vent_node[vent_len] = node;
                }
                vent_len++;
            }
        }
        st.dv_len = 0;
    }
    if (pos != R) rc = 2;
    if (!rc && (ent_len > ent_cap || vent_len > vent_cap)) rc = 1;

done:
    if (inc) {
        for (i = 0; i < n; i++) { free(inc[i].key); free(inc[i].node); }
        free(inc);
    }
    free(dn_stamp); free(dv_stamp); free(dn_list); free(dv_list);
    free(last3); free(last_vent);
    out[0] = num_nodes;
    out[1] = ent_len;
    out[2] = vent_len;
    return rc;
}
