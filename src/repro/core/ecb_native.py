"""ctypes loader for the native ECB forest engine (``_ecb_native.c``).

The stratified index plane builds |K| forests per cold build, and the
builder's zipper cascade is a scalar pointer chase that the Python
builders (`IncrementalBuilder`, `FastIncrementalBuilder`) execute at
interpreter speed. This module compiles the same algorithm — a
line-for-line port — with the host C compiler on first use, caches the
shared object under the user's temp dir keyed by a source hash, and
exposes it behind :class:`NativeForestBuilder`, which duck-types the
slice of the builder surface ``pack_index`` consumes.

Strictly optional: no compiler, a sandboxed filesystem, or
``REPRO_ECB_NATIVE=0`` all degrade to ``available() -> False`` and the
caller (``build_stratified_index``) falls back to the Python fast
builder. Output equivalence is not a risk surface: ``pack_index``
canonicalizes entry order, and tests assert the packed index is
bit-identical across all three builders.
"""

from __future__ import annotations

import ctypes
import hashlib
import os
import subprocess
import tempfile
import threading

import numpy as np

from .core_time import CoreTimeTable
from .ecb_forest import ForestInvariantError
from .temporal_graph import TemporalGraph

_SRC = os.path.join(os.path.dirname(__file__), "_ecb_native.c")

_lock = threading.Lock()
_lib = None
_tried = False

_i32p = np.ctypeslib.ndpointer(np.int32, flags="C_CONTIGUOUS")
_i64p = np.ctypeslib.ndpointer(np.int64, flags="C_CONTIGUOUS")
_u8p = np.ctypeslib.ndpointer(np.uint8, flags="C_CONTIGUOUS")


def _compile_and_load():
    with open(_SRC, "rb") as f:
        src = f.read()
    tag = hashlib.sha256(src).hexdigest()[:16]
    so = os.path.join(tempfile.gettempdir(), f"repro_ecb_{tag}.so")
    if not os.path.exists(so):
        tmp = f"{so}.{os.getpid()}.tmp"
        cc = os.environ.get("CC", "cc")
        subprocess.run([cc, "-O2", "-shared", "-fPIC", "-o", tmp, _SRC],
                       check=True, capture_output=True)
        os.replace(tmp, so)  # atomic: concurrent compilers race benignly
    lib = ctypes.CDLL(so)
    lib.ecb_run.restype = ctypes.c_int
    lib.ecb_run.argtypes = [
        ctypes.c_int32, ctypes.c_int32, ctypes.c_int64, ctypes.c_int64,
        _i32p, _i32p,                      # esrc, edst
        _i64p, _i64p, _i64p,               # e_sorted, c_sorted, neg_ts
        _i32p, _i32p, _i32p, _i32p,        # n_edge, n_ct, n_u, n_v
        _i64p, _i32p, _i32p,               # n_rank, n_live_from, n_live_to
        _i32p, _i32p, _i32p, _u8p,         # n_parent, n_child0/1, n_in
        ctypes.c_int64, _i32p, _i32p, _i32p, _i32p, _i32p,   # ent buffers
        ctypes.c_int64, _i32p, _i32p, _i32p,                 # vent buffers
        _i64p,                             # out counters
    ]
    return lib


def _load():
    global _lib, _tried
    if _tried:
        return _lib
    with _lock:
        if _tried:
            return _lib
        if os.environ.get("REPRO_ECB_NATIVE", "1") != "0":
            try:
                _lib = _compile_and_load()
            except Exception:
                _lib = None
        _tried = True
        return _lib


def available() -> bool:
    """True when the compiled engine is importable on this host."""
    return _load() is not None


class NativeForestBuilder:
    """Builder facade over the native run; exposes exactly the state
    ``pack_index`` reads (plus parent/child arrays for invariant tests),
    with the same semantics as the Python builders after ``run()``."""

    def __init__(self, g: TemporalGraph, tab: CoreTimeTable):
        lib = _load()
        if lib is None:
            raise RuntimeError("native ECB engine unavailable "
                               "(no compiler or REPRO_ECB_NATIVE=0)")
        self._lib = lib
        self.g = g
        self.tab = tab
        self.num_nodes = 0

    def run(self) -> "NativeForestBuilder":
        g, tab = self.g, self.tab
        R = tab.num_versions
        order = np.lexsort((tab.edge_id, tab.ct, -tab.ts_to))
        e_sorted = np.ascontiguousarray(tab.edge_id[order], np.int64)
        c_sorted = np.ascontiguousarray(tab.ct[order], np.int64)
        neg_ts = np.ascontiguousarray(-tab.ts_to[order], np.int64)
        esrc = np.ascontiguousarray(g.src, np.int32)
        edst = np.ascontiguousarray(g.dst, np.int32)

        z32 = lambda size: np.zeros(max(size, 1), np.int32)
        self.n_edge, self.n_ct = z32(R), z32(R)
        self.n_u, self.n_v = z32(R), z32(R)
        self.n_rank = np.zeros(max(R, 1), np.int64)
        self.n_live_from, self.n_live_to = z32(R), z32(R)
        n_parent, n_child0, n_child1 = z32(R), z32(R), z32(R)
        n_in = np.zeros(max(R, 1), np.uint8)
        out = np.zeros(3, np.int64)

        ent_cap = 4 * R + 1024
        vent_cap = 2 * R + 2 * g.n + 1024
        for _ in range(2):  # second pass only if the size guess was low
            ent = [z32(ent_cap) for _ in range(5)]
            vent = [z32(vent_cap) for _ in range(3)]
            rc = self._lib.ecb_run(
                g.n, tab.t_max, np.int64(g.m + 1), R,
                esrc, edst, e_sorted, c_sorted, neg_ts,
                self.n_edge, self.n_ct, self.n_u, self.n_v,
                self.n_rank, self.n_live_from, self.n_live_to,
                n_parent, n_child0, n_child1, n_in,
                ent_cap, *ent, vent_cap, *vent, out)
            if rc != 1:
                break
            ent_cap, vent_cap = int(out[1]), int(out[2])
        if rc == 3:
            raise MemoryError("native ECB engine out of memory")
        if rc:
            raise ForestInvariantError(
                f"native ECB engine failed with code {rc}")
        N = int(out[0])
        self.num_nodes = N
        self.n_parent = n_parent
        self.n_child = np.stack([n_child0, n_child1], axis=1)
        self.n_in = n_in.astype(bool)
        ne, nv = int(out[1]), int(out[2])
        (self.ent_node, self.ent_ts, self.ent_l, self.ent_r,
         self.ent_p) = (a[:ne] for a in ent)
        self.vent_vert, self.vent_ts, self.vent_node = (a[:nv] for a in vent)
        return self
