"""Temporal graph representation and workload generators.

A temporal graph is an undirected multigraph whose edges carry integer
timestamps. Per the paper (§2) timestamps form a contiguous integer range
starting at 1; ``t_max`` is the largest timestamp. The projected graph
``G_[ts,te]`` keeps the edges whose timestamp lies in the window.

The canonical in-memory layout is struct-of-arrays (``src``, ``dst``, ``t``)
in int32/int64 so the same object feeds the numpy oracle, the JAX engines and
the Pallas kernels without conversion.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TemporalGraph:
    """Undirected temporal multigraph in edge-list (SoA) form.

    Edges are stored sorted by ``(t, src, dst)``; edge id == array index, so
    the paper's tie-break on "edge ID" is reproducible.
    """

    n: int                     # number of vertices (ids 0..n-1)
    src: np.ndarray            # int32[m]
    dst: np.ndarray            # int32[m]
    t: np.ndarray              # int32[m], timestamps in [1, t_max]

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def t_max(self) -> int:
        # Cached in __post_init__: the serving path and the workload
        # generators hit this per request, and arrays are immutable here.
        return self._t_max

    def __post_init__(self):
        if not (self.src.shape == self.dst.shape == self.t.shape):
            raise ValueError(
                f"edge arrays disagree: src{self.src.shape} "
                f"dst{self.dst.shape} t{self.t.shape}")
        if self.m:
            if int(self.src.max()) >= self.n or int(self.dst.max()) >= self.n:
                raise ValueError(
                    f"endpoint id >= n={self.n} "
                    f"(max src={int(self.src.max())}, "
                    f"dst={int(self.dst.max())})")
            if int(self.t.min()) < 1:
                raise ValueError(
                    f"timestamps must be >= 1, got min {int(self.t.min())}")
        object.__setattr__(self, "_t_max", int(self.t.max()) if self.m else 0)

    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(n: int, edges: Iterable[tuple[int, int, int]]) -> "TemporalGraph":
        """Build from ``(u, v, t)`` triples; sorts by (t, u, v), dedups nothing
        (parallel temporal edges are legal), drops self-loops (degenerate for
        k-core)."""
        arr = np.asarray([(u, v, t) for (u, v, t) in edges if u != v], dtype=np.int64)
        if arr.size == 0:
            z = np.zeros(0, np.int32)
            return TemporalGraph(n, z, z.copy(), z.copy())
        order = np.lexsort((arr[:, 1], arr[:, 0], arr[:, 2]))
        arr = arr[order]
        return TemporalGraph(
            n,
            arr[:, 0].astype(np.int32),
            arr[:, 1].astype(np.int32),
            arr[:, 2].astype(np.int32),
        )

    # -- streaming epochs ----------------------------------------------
    def extend(self, edges: Iterable[tuple[int, int, int]]) -> "TemporalGraph":
        """Append *suffix* edges (all strictly newer than ``t_max``) and
        return the next graph epoch.

        The suffix condition is what makes the streaming plane cheap and
        exact: because edges are stored sorted by ``(t, src, dst)``, a
        suffix append keeps every existing edge id (the old edge arrays are
        a prefix of the new ones), so core-time tables, PECB indexes and
        cached results built for this epoch remain valid for every window
        with ``te <= t_max`` and can be *extended* rather than rebuilt
        (``core_time.extend_core_times``, ``pecb_index.build_pecb_index``
        with ``resume_from``). Out-of-order (historical) edges are
        rejected: they would invalidate the prefix property and require a
        cold rebuild — callers wanting that should build a new graph.

        Self-loops are dropped (as in :meth:`from_edges`); an empty
        ``edges`` returns ``self``.
        """
        arr = np.asarray(
            [(u, v, t) for (u, v, t) in edges if u != v], dtype=np.int64)
        if arr.size == 0:
            return self
        if int(arr[:, 2].min()) <= self.t_max:
            raise ValueError(
                f"extend() takes suffix edges only: got timestamp "
                f"{int(arr[:, 2].min())} <= t_max={self.t_max}; historical "
                "edges need a cold rebuild (TemporalGraph.from_edges)")
        if int(arr[:, :2].max()) >= self.n or int(arr[:, :2].min()) < 0:
            raise ValueError(
                f"extend() edge endpoints must lie in [0, {self.n})")
        order = np.lexsort((arr[:, 1], arr[:, 0], arr[:, 2]))
        arr = arr[order]
        return TemporalGraph(
            self.n,
            np.concatenate([self.src, arr[:, 0].astype(np.int32)]),
            np.concatenate([self.dst, arr[:, 1].astype(np.int32)]),
            np.concatenate([self.t, arr[:, 2].astype(np.int32)]),
        )

    def expire_before(self, t_cut: int) -> "TemporalGraph":
        """Drop every edge with timestamp ``< t_cut`` (prefix expiry) and
        return the next graph epoch with surviving timestamps *shifted* to
        start at 1 again (new ``t`` = old ``t - (t_cut - 1)``).

        The shift is what keeps long-running deployments bounded: every
        downstream structure — the dense ``vertex_ct`` matrix, the packed
        index's per-ts entry streams, device buffers — is sized by
        ``t_max``, so retention must shrink the time axis, not merely thin
        the edge list. The shifted epoch is exactly the graph a cold
        ``from_edges`` build over the surviving triples would produce:
        edges stay sorted by ``(t, src, dst)`` (a constant shift preserves
        the order) and the surviving edges keep their relative ids
        (new id = old id - #expired), which is what lets
        ``core_time.shrink_core_times`` / ``streaming.shrink_pecb_index``
        reduce the retained indices by pure slicing instead of a rebuild.

        ``t_cut <= 1`` expires nothing and returns ``self``; ``t_cut >
        t_max`` expires everything (an empty epoch over the same vertex
        set). Note a cut below the smallest timestamp still *shifts* —
        retention contracts the timeline, not just the edge list.
        """
        t_cut = int(t_cut)
        if t_cut <= 1:
            return self
        cut = int(np.searchsorted(self.t, t_cut, side="left"))
        return TemporalGraph(
            self.n,
            np.ascontiguousarray(self.src[cut:]),
            np.ascontiguousarray(self.dst[cut:]),
            np.ascontiguousarray(self.t[cut:] - np.int32(t_cut - 1)),
        )

    def retain_last(self, w: int) -> "TemporalGraph":
        """Sliding-window retention: keep only the last ``w`` timestamps
        (``expire_before(t_max - w + 1)``). ``w >= t_max`` keeps everything
        and returns ``self``."""
        if w <= 0:
            raise ValueError(f"retention window must be positive, got {w}")
        return self.expire_before(self.t_max - int(w) + 1)

    def split_at(self, t: int) -> tuple["TemporalGraph", np.ndarray]:
        """(epoch graph of edges with timestamp <= t, suffix triples after
        ``t`` as an int64[(s, 3)] array) — the replay harness for streaming
        benchmarks/tests: ``g0.extend(suffix)`` reproduces ``self``."""
        cut = int(np.searchsorted(self.t, t, side="right"))
        g0 = TemporalGraph(self.n, self.src[:cut], self.dst[:cut],
                           self.t[:cut])
        suffix = np.stack([self.src[cut:], self.dst[cut:],
                           self.t[cut:]], axis=1).astype(np.int64)
        return g0, suffix

    def window_mask(self, ts: int, te: int) -> np.ndarray:
        return (self.t >= ts) & (self.t <= te)

    def project(self, ts: int, te: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Edge arrays of the projected graph ``G_[ts,te]`` plus edge ids."""
        mask = self.window_mask(ts, te)
        ids = np.nonzero(mask)[0]
        return self.src[ids], self.dst[ids], ids

    def remap_timestamps(self) -> "TemporalGraph":
        """Densify timestamps to 1..#distinct (paper's contiguity assumption)."""
        uniq, inv = np.unique(self.t, return_inverse=True)
        return TemporalGraph(self.n, self.src, self.dst, (inv + 1).astype(np.int32))

    def aggregate_days(self, edges_per_day: int) -> "TemporalGraph":
        """Coarsen timestamps (the paper's day-level aggregation, §6)."""
        t = ((self.t - 1) // edges_per_day + 1).astype(np.int32)
        return TemporalGraph(self.n, self.src, self.dst, t)


# ----------------------------------------------------------------------
# Synthetic workload generators (offline container: Table 3 datasets are not
# downloadable; these mimic their shape — power-law degrees, bursty times).
# ----------------------------------------------------------------------

def gen_temporal_graph(
    n: int,
    m: int,
    t_max: int,
    *,
    seed: int = 0,
    power: float = 1.2,
    burstiness: float = 0.35,
) -> TemporalGraph:
    """Power-law-ish temporal graph.

    Vertex popularity ~ Zipf(power); each edge picks endpoints by popularity;
    timestamps are a mixture of uniform and "bursty" (repeat-previous) draws,
    which produces the core-time clustering real interaction graphs show.
    """
    rng = np.random.default_rng(seed)
    pop = (np.arange(1, n + 1, dtype=np.float64)) ** (-power)
    pop /= pop.sum()
    u = rng.choice(n, size=2 * m, p=pop).astype(np.int64)
    src, dst = u[:m], u[m:]
    fix = src == dst
    dst[fix] = (src[fix] + 1 + rng.integers(0, n - 1, fix.sum())) % n
    t = rng.integers(1, t_max + 1, size=m)
    # bursts: a fraction of edges reuse the timestamp of a random earlier edge
    nb = int(burstiness * m)
    if nb and m > 1:
        idx = rng.integers(1, m, size=nb)
        t[idx] = t[idx - 1]
    return TemporalGraph.from_edges(n, zip(src.tolist(), dst.tolist(), t.tolist())).remap_timestamps()


#: Named benchmark workloads, shaped after Table 3 (reduced scale).
BENCH_WORKLOADS: dict[str, dict] = {
    "fb_like": dict(n=300, m=4000, t_max=160, seed=1),      # FB-Forum-ish
    "cm_like": dict(n=600, m=9000, t_max=190, seed=2),      # CollegeMsg-ish
    "em_like": dict(n=400, m=20000, t_max=260, seed=3),     # Email-ish (dense)
    "mo_like": dict(n=2000, m=24000, t_max=700, seed=4),    # MathOverflow-ish
    "wk_like": dict(n=3000, m=60000, t_max=150, seed=5),    # Wikipedia-ish (few days)
}


def bench_graph(name: str) -> TemporalGraph:
    return gen_temporal_graph(**BENCH_WORKLOADS[name])


def random_queries(g: TemporalGraph, n_q: int, seed: int = 0) -> list[tuple[int, int, int]]:
    """Random (u, ts, te) TCCS queries over the graph's time range — the
    query distribution shared by benchmarks and serving drivers."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, g.n, n_q)
    if g.t_max == 0:          # empty graph: every window is empty anyway
        return [(int(v), 1, 0) for v in u]
    ts = rng.integers(1, g.t_max + 1, n_q)
    te = np.minimum(ts + rng.integers(0, g.t_max, n_q), g.t_max)
    return list(zip(u.tolist(), ts.tolist(), te.tolist()))


def gen_contact_network(n: int, days: int, *, seed: int = 0, meetings_per_day: int | None = None) -> TemporalGraph:
    """Contact-tracing style workload: small-world daily meetings."""
    rng = np.random.default_rng(seed)
    meetings_per_day = meetings_per_day or 4 * n
    edges = []
    home = rng.integers(0, max(1, n // 20), size=n)  # household clusters
    for day in range(1, days + 1):
        a = rng.integers(0, n, size=meetings_per_day)
        same = rng.random(meetings_per_day) < 0.5
        b = np.where(
            same,
            (a + rng.integers(1, 6, meetings_per_day)) % n,  # near ids = same household-ish
            rng.integers(0, n, size=meetings_per_day),
        )
        keep = a != b
        edges.extend(zip(a[keep].tolist(), b[keep].tolist(), [day] * int(keep.sum())))
    return TemporalGraph.from_edges(n, edges)
