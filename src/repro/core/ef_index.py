"""EF-Index — behaviourally-faithful reimplementation of the SOTA baseline
(Yang et al. [32], paper §3.1), per DESIGN.md §5.

The original EF-Index (a full paper on its own) enumerates every distinct
temporal k-core over all windows with OTCD (cost ``O(t_max^2 · V_k)``),
organizes them into a lineage graph, covers the lineages with chains
(Hopcroft–Karp), and stores one Minimum Temporal Spanning Forest per chain.
Queries look up the TTI chain and run a label-constrained DFS.

This reimplementation preserves the *complexity profile* the paper measures
against, with documented simplifications that are neutral or favour EF:

* **OTCD-style enumeration** — for every start time, every core changepoint
  (distinct edge core-time) materialises the grown core; each (window ×
  member-edge) pair is touched, reproducing the quadratic build cost. Cores
  are deduplicated across start times by (count, 64-bit mix-hash) instead of
  full edge-set keys — same dedup effect, less build RAM (favours EF).
* **Chains** — for a fixed start time the cores for growing ``te`` form a
  containment chain (the natural lineage); consecutive start times with an
  identical chain share one stored forest (the chain-cover effect). Each
  stored chain keeps a *full* MTSF with per-edge validity labels — the
  per-chain storage redundancy the paper's Figure 4 measures.
* **Lookup** — window -> chain resolution is a direct array index (O(1),
  faster than the paper's ``O(d·log p_max)`` — favours EF query time).
* Queries are exact (tested against the brute-force oracle).
"""

from __future__ import annotations

import bisect
import warnings

import numpy as np

from .core_time import CoreTimeTable, edge_core_times
from .ctmsf import kruskal_msf
from .ecb_forest import active_versions
from .query_api import ComponentBackend, VersionStore
from .temporal_graph import TemporalGraph


def _mix(h: int, x: int) -> int:
    # splitmix64-style mix; order-independent combination via addition
    x = (x ^ (x >> 30)) * 0xBF58476D1CE4E5B9 & 0xFFFFFFFFFFFFFFFF
    x = (x ^ (x >> 27)) * 0x94D049BB133111EB & 0xFFFFFFFFFFFFFFFF
    return (h + (x ^ (x >> 31))) & 0xFFFFFFFFFFFFFFFF


class _ChainForest:
    """One stored MTSF: CSR adjacency over graph vertices with ct labels."""

    __slots__ = ("vptr", "adj_node", "node_u", "node_v", "node_ct", "nbytes")

    def __init__(self, n: int, u: np.ndarray, v: np.ndarray, ct: np.ndarray):
        deg = np.bincount(u, minlength=n) + np.bincount(v, minlength=n)
        self.vptr = np.zeros(n + 1, np.int64)
        np.cumsum(deg, out=self.vptr[1:])
        pos = self.vptr[:-1].copy()
        nn = u.shape[0]
        self.adj_node = np.zeros(2 * nn, np.int32)
        for i in range(nn):
            a, b = int(u[i]), int(v[i])
            self.adj_node[pos[a]] = i
            pos[a] += 1
            self.adj_node[pos[b]] = i
            pos[b] += 1
        self.node_u = u.astype(np.int32)
        self.node_v = v.astype(np.int32)
        self.node_ct = ct.astype(np.int32)
        self.nbytes = (self.vptr.nbytes + self.adj_node.nbytes +
                       self.node_u.nbytes + self.node_v.nbytes + self.node_ct.nbytes)


class EFIndex(ComponentBackend):
    backend_name = "ef"

    def __init__(self, g: TemporalGraph, k: int, tab: CoreTimeTable | None = None):
        self.g = g
        self.k = k
        tab = tab if tab is not None else edge_core_times(g, k)
        self.versions = VersionStore.from_table(g, k, tab)  # v2 surface
        t_max = g.t_max
        self.t_max = t_max

        # ---- OTCD-style enumeration + lineage chains --------------------
        core_ids: dict[tuple, int] = {}     # (size, hash) -> core id
        self.num_distinct_cores = 0
        self.enumerated_core_edges = 0      # Σ |core| over all windows (cost meter)
        chain_sigs: list[tuple] = []        # per ts: tuple of core ids (the chain)
        forests: list[_ChainForest] = []
        self.ts_to_forest = np.zeros(t_max + 2, np.int64)

        prev_sig = None
        for ts in range(1, t_max + 1):
            e_ids, cts = active_versions(tab, ts)   # ascending (ct, edge)
            # changepoints of te: distinct core times
            sig = []
            h, cnt = 0, 0
            j = 0
            nn = e_ids.shape[0]
            while j < nn:
                c = cts[j]
                while j < nn and cts[j] == c:
                    h = _mix(h, int(e_ids[j]))
                    cnt += 1
                    j += 1
                # the temporal k-core of [ts, c]: every member edge touched
                self.enumerated_core_edges += cnt
                key = (cnt, h)
                if key not in core_ids:
                    core_ids[key] = len(core_ids)
                sig.append(core_ids[key])
            sig = tuple(sig)
            if prev_sig is not None and sig == prev_sig:
                # identical chain: share the previous forest (chain cover)
                self.ts_to_forest[ts] = self.ts_to_forest[ts - 1]
            else:
                u = g.src[e_ids].astype(np.int64)
                v = g.dst[e_ids].astype(np.int64)
                keep = kruskal_msf(u, v, cts.astype(np.int64), g.n)
                forests.append(_ChainForest(g.n, u[keep], v[keep], cts[keep]))
                self.ts_to_forest[ts] = len(forests) - 1
            prev_sig = sig
        self.num_distinct_cores = len(core_ids)
        self.forests = forests

    def nbytes(self) -> int:
        return int(self.ts_to_forest.nbytes + sum(f.nbytes for f in self.forests))

    # -- label-constrained DFS over the chain's MTSF ----------------------
    def query(self, u: int, ts: int, te: int) -> set[int]:
        """Deprecated positional shim; prefer ``answer(TCCSQuery(...))``.
        Emits :class:`DeprecationWarning`."""
        warnings.warn(
            "EFIndex.query(u, ts, te) is deprecated; use "
            "answer(TCCSQuery(u, ts, te, k))",
            DeprecationWarning, stacklevel=2)
        return self._component_vertices(u, ts, te)

    def _component_vertices(self, u: int, ts: int, te: int) -> set[int]:
        if not (1 <= ts <= self.t_max):
            return set()
        f = self.forests[int(self.ts_to_forest[ts])]
        lo, hi = int(f.vptr[u]), int(f.vptr[u + 1])
        if not any(f.node_ct[f.adj_node[i]] <= te for i in range(lo, hi)):
            return set()
        seen: set[int] = set()
        stack = [u]
        while stack:
            x = stack.pop()
            if x in seen:
                continue
            seen.add(x)
            for i in range(int(f.vptr[x]), int(f.vptr[x + 1])):
                node = int(f.adj_node[i])
                if f.node_ct[node] > te:
                    continue
                y = int(f.node_u[node]) if int(f.node_v[node]) == x else int(f.node_v[node])
                if y not in seen:
                    stack.append(y)
        return seen
