"""Historical k-core search core: temporal graphs, core times, the ECB
forest / PECB index and baselines, the batched device query plane, and the
typed Query API v2 surface (DESIGN.md §8) they all answer through."""

from .query_api import (
    EdgeSet,
    InvalidQueryError,
    Provenance,
    ResultMode,
    TCCSBackend,
    TCCSQuery,
    TCCSResult,
    VersionStore,
    WindowSweep,
)

__all__ = [
    "EdgeSet", "InvalidQueryError", "Provenance", "ResultMode",
    "TCCSBackend", "TCCSQuery", "TCCSResult", "VersionStore", "WindowSweep",
]
