"""Historical k-core search core: temporal graphs, core times, the ECB
forest / PECB index and baselines, the batched device query plane, the
typed Query API v2 surface (DESIGN.md §8) they all answer through, and the
streaming epoch plane (DESIGN.md §9: ``TemporalGraph.extend`` +
``extend_core_times`` + ``extend_pecb_index``) with its sliding-window
retention counterpart (DESIGN.md §10: ``TemporalGraph.expire_before`` /
``retain_last`` + ``shrink_core_times`` + ``shrink_pecb_index``)."""

from .query_api import (
    EdgeSet,
    InvalidQueryError,
    Provenance,
    ResultMode,
    TCCSBackend,
    TCCSQuery,
    TCCSResult,
    VersionStore,
    WindowSweep,
)

__all__ = [
    "EdgeSet", "InvalidQueryError", "Provenance", "ResultMode",
    "TCCSBackend", "TCCSQuery", "TCCSResult", "VersionStore", "WindowSweep",
]
