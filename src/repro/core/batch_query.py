"""Batched TCCS query engine (device plane; beyond-paper, DESIGN.md §3, §8).

Algorithm 1 answers one query in tens of microseconds on a CPU by chasing
pointers. A TPU should instead answer *thousands of queries per launch*.
This module evaluates a whole batch ``(u_b, ts_b, te_b)`` at once against the
packed PECB arrays:

1. **Entry points** — the paper's per-vertex lookup (Alg 1 line 3) becomes a
   vectorized lower-bound binary search over the per-vertex version CSR.
2. **Link resolution** — the paper's per-node binary search (Alg 1 line 10)
   becomes a ``(B, N)`` vectorized lower-bound over the per-node entry CSR:
   for every query b and forest node x we resolve (left, right, parent) at
   ``ts_b`` in ``O(log t̄)`` steps, all queries and nodes in parallel.
3. **Traversal** — BFS becomes masked min-label propagation with pointer
   jumping over the (≤3-regular!) forest links: per round each active node
   takes the min label over itself and its valid neighbours, then compresses
   ``label ← label[label]``. The binary bound on children is exactly what
   keeps each round at three gathers. Converges in O(log N) rounds for
   balanced forests (worst case O(depth)); the fixpoint is detected by a
   ``lax.while_loop``.

Node activity masking uses the forest-membership lifetimes recorded by the
builder: a node participates for query b iff
``live_from <= ts_b <= live_to`` and ``ct <= te_b``. This is what makes the
stale entries of expired nodes harmless here (the host DFS never reaches
them; the data-parallel propagation must mask them explicitly).

Query API v2 additions (DESIGN.md §8):

* :func:`batch_query_full` — besides the vertex mask, derives **edge
  membership** on device: the converged labels give forest-node membership
  (``label[b, x] == label[b, entry_b]``, the masked gather inside
  :func:`_component_masks` that already produces the vertex mask), and a
  *core-time version* j is then a member iff its record covers ``ts_b``,
  ``ct_j <= te_b`` and the vertex mask is set at its ``src`` endpoint (one
  gather over the version arrays, :func:`_version_member`). The resulting
  ``(B, V)`` mask is exact against the brute-force induced-edge oracle —
  it feeds the EDGES/SUBGRAPH result modes without any host-side graph
  traversal.
* :func:`window_sweep` — the same vertex over W sliding windows in ONE
  launch (the contact-tracing trajectory query). The per-vertex entry
  segment ``[vrow_ptr[u], vrow_ptr[u+1])`` is resolved once and shared by
  all windows; everything downstream reuses the batched propagation core
  with B = W.

Output equality with Algorithm 1 (and, for edge modes, with
``kcore.tccs_oracle_edges``) is asserted in tests for random graphs and
random query batches.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import contracts as kernel_contracts
from .pecb_index import PECBIndex, StratifiedPECB

NONE = -1

_I32_MIN = np.iinfo(np.int32).min
_I32_MAX = np.iinfo(np.int32).max


class LayoutOverflowError(OverflowError):
    """A device-layout value does not fit int32.

    The packed layout keeps every array int32 on device (half the
    transfer and VMEM footprint of int64), which is only sound while the
    global id/offset space — the stratified ``K*n+1`` row-pointer rows,
    the fused entry offsets, the ``k_index*n + u`` query slots — stays
    below 2**31. The layout builders compute in int64 and narrow through
    :func:`_i32`, which raises this at *build* time instead of letting
    the device index silently wrap."""


def _i32(a, what: str = "array") -> np.ndarray:
    """Checked int32 narrowing for layout arrays (the dtype-flow pass
    treats calls to this as guarded; a raw ``np.asarray(x, np.int32)`` of
    packed-extent arithmetic is a finding)."""
    arr = np.asarray(a)
    if arr.size:
        mx, mn = int(arr.max()), int(arr.min())
        if mx > _I32_MAX or mn < _I32_MIN:
            raise LayoutOverflowError(
                f"{what}: value range [{mn}, {mx}] exceeds int32; the "
                "packed device layout cannot address this index — shard "
                "the workload or shrink the stratum set")
    return arr.astype(np.int32, copy=False)


@dataclasses.dataclass(frozen=True)
class DeviceIndex:
    """PECB arrays on device + static metadata (hashable for jit)."""

    n: int
    t_max: int
    node_u: jnp.ndarray
    node_v: jnp.ndarray
    node_ct: jnp.ndarray
    live_from: jnp.ndarray
    live_to: jnp.ndarray
    row_ptr: jnp.ndarray
    ent_ts: jnp.ndarray
    ent_left: jnp.ndarray
    ent_right: jnp.ndarray
    ent_parent: jnp.ndarray
    vrow_ptr: jnp.ndarray
    vent_ts: jnp.ndarray
    vent_node: jnp.ndarray
    # core-time version arrays (query API v2: EDGES/SUBGRAPH modes).
    # Padded to length >= 1 with inert records (ts_from=1, ts_to=0).
    ver_ts_from: jnp.ndarray
    ver_ts_to: jnp.ndarray
    ver_ct: jnp.ndarray
    ver_src: jnp.ndarray
    ver_k: jnp.ndarray        # per-version stratum k (constant per-k mirror)
    max_node_entries: int     # static: longest per-node entry list
    max_vert_entries: int     # static: longest per-vertex entry list
    num_versions: int         # static: true version count (pre-padding)

    @property
    def num_nodes(self) -> int:
        return int(self.node_u.shape[0])


_ARRAY_FIELDS = (
    "node_u", "node_v", "node_ct", "live_from", "live_to",
    "row_ptr", "ent_ts", "ent_left", "ent_right", "ent_parent",
    "vrow_ptr", "vent_ts", "vent_node",
    "ver_ts_from", "ver_ts_to", "ver_ct", "ver_src", "ver_k",
)
_META_FIELDS = ("n", "t_max", "max_node_entries", "max_vert_entries",
                "num_versions")

jax.tree_util.register_pytree_node(
    DeviceIndex,
    lambda d: (tuple(getattr(d, f) for f in _ARRAY_FIELDS),
               tuple(getattr(d, f) for f in _META_FIELDS)),
    lambda meta, arrs: DeviceIndex(**dict(zip(_META_FIELDS, meta)),
                                   **dict(zip(_ARRAY_FIELDS, arrs))),
)


def _host_layout(index):
    """(meta dict, name -> int32 host array) in the device layout — the
    single source of truth for ``to_device`` and ``refresh_device``
    (including the length->=1 inert padding of optional arrays).

    Accepts a per-k :class:`PECBIndex` or a whole :class:`StratifiedPECB`
    (routed to :func:`_host_layout_stratified`: all strata in one global
    id space, servable by the same compiled programs)."""
    if isinstance(index, StratifiedPECB):
        return _host_layout_stratified(index)
    i32 = _i32
    seg = np.diff(index.row_ptr)
    vseg = np.diff(index.vrow_ptr)
    store = index.versions
    has_vers = store is not None and store.num_versions > 0
    pad0 = np.zeros((1,), np.int32)
    padn = np.full((1,), NONE, np.int32)
    arrays = {
        "node_u": i32(index.node_u),
        "node_v": i32(index.node_v),
        "node_ct": i32(index.node_ct),
        "live_from": i32(index.node_live_from),
        "live_to": i32(index.node_live_to),
        "row_ptr": i32(index.row_ptr),
        "ent_ts": i32(index.ent_ts) if index.ent_ts.size else pad0,
        "ent_left": i32(index.ent_left) if index.ent_left.size else padn,
        "ent_right": i32(index.ent_right) if index.ent_right.size else padn,
        "ent_parent": i32(index.ent_parent) if index.ent_parent.size else padn,
        "vrow_ptr": i32(index.vrow_ptr),
        "vent_ts": i32(index.vent_ts) if index.vent_ts.size else pad0,
        "vent_node": i32(index.vent_node) if index.vent_node.size else padn,
        "ver_ts_from": i32(store.ts_from) if has_vers else np.ones((1,), np.int32),
        "ver_ts_to": i32(store.ts_to) if has_vers else pad0,
        "ver_ct": i32(store.ct) if has_vers else pad0,
        "ver_src": i32(store.src) if has_vers else pad0,
        "ver_k": (np.full(store.num_versions, index.k, np.int32)
                  if has_vers else pad0),
    }
    meta = {
        "n": index.n,
        "t_max": index.t_max,
        "max_node_entries": int(seg.max()) if seg.size else 0,
        "max_vert_entries": int(vseg.max()) if vseg.size else 0,
        "num_versions": store.num_versions if has_vers else 0,
    }
    return meta, arrays


def _host_layout_stratified(sx: StratifiedPECB):
    """Device layout for a whole k-stratified index.

    The per-stratum blocks are fused into ONE global node/entry id space:
    node ids shift by ``knode_ptr[ki]``, the per-stratum CSRs re-base onto
    the concatenated entry arrays, and per-vertex lookup becomes a lookup
    on the *slot* ``ki * n + u`` (``vrow_ptr`` has ``|K|*n+1`` rows). The
    strata stay link-disjoint, so :func:`batch_query`'s min-label
    propagation serves a mixed-k batch unchanged — per-query k enters only
    as the host-computed entry slot, plus the ``ver_k == kq`` filter of
    :func:`batch_query_full_mixed` (the version arrays are the one place
    where records of different strata share an index space).
    """
    i32 = _i32
    K = len(sx.ks)
    n = sx.n
    Ntot = sx.num_nodes
    Etot = int(sx.ent_ts.shape[0])
    VEtot = int(sx.vent_ts.shape[0])

    row_ptr = np.empty(Ntot + 1, np.int64)
    vrow_ptr = np.empty(K * n + 1, np.int64)
    ent_l = sx.ent_left.astype(np.int64)
    ent_r = sx.ent_right.astype(np.int64)
    ent_p = sx.ent_parent.astype(np.int64)
    vent_node = sx.vent_node.astype(np.int64)
    for ki in range(K):
        s, e = int(sx.knode_ptr[ki]), int(sx.knode_ptr[ki + 1])
        row_ptr[s:e] = (sx.row_ptr[s + ki:e + ki].astype(np.int64)
                        + int(sx.kent_ptr[ki]))
        vrow_ptr[ki * n:(ki + 1) * n] = (
            sx.vrow_ptr[ki * (n + 1):ki * (n + 1) + n].astype(np.int64)
            + int(sx.kvent_ptr[ki]))
        off = int(sx.knode_ptr[ki])
        if off:
            for seg in (ent_l[int(sx.kent_ptr[ki]):int(sx.kent_ptr[ki + 1])],
                        ent_r[int(sx.kent_ptr[ki]):int(sx.kent_ptr[ki + 1])],
                        ent_p[int(sx.kent_ptr[ki]):int(sx.kent_ptr[ki + 1])],
                        vent_node[int(sx.kvent_ptr[ki]):
                                  int(sx.kvent_ptr[ki + 1])]):
                seg[seg >= 0] += off
    row_ptr[Ntot] = Etot
    vrow_ptr[K * n] = VEtot

    st = sx.strata
    V = int(st.num_versions) if st is not None else 0
    seg = np.diff(row_ptr)
    vseg = np.diff(vrow_ptr)
    pad0 = np.zeros((1,), np.int32)
    padn = np.full((1,), NONE, np.int32)
    arrays = {
        "node_u": i32(sx.node_u),
        "node_v": i32(sx.node_v),
        "node_ct": i32(sx.node_ct),
        "live_from": i32(sx.node_live_from),
        "live_to": i32(sx.node_live_to),
        "row_ptr": _i32(row_ptr, "fused entry row_ptr"),
        "ent_ts": i32(sx.ent_ts) if Etot else pad0,
        "ent_left": i32(ent_l) if Etot else padn,
        "ent_right": i32(ent_r) if Etot else padn,
        "ent_parent": i32(ent_p) if Etot else padn,
        "vrow_ptr": _i32(vrow_ptr, "fused K*n vertex row_ptr"),
        "vent_ts": i32(sx.vent_ts) if VEtot else pad0,
        "vent_node": i32(vent_node) if VEtot else padn,
        "ver_ts_from": i32(st.ts_from) if V else np.ones((1,), np.int32),
        "ver_ts_to": i32(st.ts_to) if V else pad0,
        "ver_ct": i32(st.ct) if V else pad0,
        "ver_src": i32(sx.ver_src) if V else pad0,
        "ver_k": (np.repeat(np.asarray(sx.ks, np.int32),
                            np.diff(st.kptr)).astype(np.int32)
                  if V else pad0),
    }
    meta = {
        "n": n,
        "t_max": sx.t_max,
        "max_node_entries": int(seg.max()) if seg.size else 0,
        "max_vert_entries": int(vseg.max()) if vseg.size else 0,
        "num_versions": V,
    }
    return meta, arrays


def to_device(index) -> DeviceIndex:
    """Upload a :class:`PECBIndex` or a whole :class:`StratifiedPECB`
    (mixed-k servable) to the device."""
    meta, arrays = _host_layout(index)
    if kernel_contracts.witness_enabled():
        kernel_contracts.check_layout(arrays,
                                      witness=kernel_contracts.WITNESS)
    return DeviceIndex(**meta,
                       **{k: jnp.asarray(v) for k, v in arrays.items()})


def refresh_device(prev_host: PECBIndex, prev_dev: DeviceIndex,
                   new_host: PECBIndex) -> tuple[DeviceIndex, dict]:
    """Refresh a device mirror across a streaming epoch, re-uploading only
    what changed.

    Per array (compared in the shared host layout): if the new array equals
    the old one, the resident device buffer is reused outright (zero
    transfer); if the old array is a strict prefix of the new one (a pure
    suffix grow), only the suffix is shipped and concatenated on device;
    otherwise the array is uploaded in full. Always exact — the result is
    indistinguishable from ``to_device(new_host)`` (test-asserted); the
    returned stats (``reused_bytes``/``uploaded_bytes`` + per-kind counts)
    make the transfer savings observable to the registry's refresh metrics.

    Retention epochs (``streaming.shrink_pecb_index``) land here too: a
    shrunk index shares no bytes with its predecessor (every surviving
    value is shifted), so each array takes the full-upload path — smaller
    than the buffer it replaces. ``freed_bytes`` records the net device
    memory returned by the swap (old mirror bytes minus new), the
    observable behind the bounded-memory claim the retention bench
    asserts; it is 0 for grow refreshes.
    """
    _, old_arrays = _host_layout(prev_host)
    meta, new_arrays = _host_layout(new_host)
    stats = {"reused": 0, "suffix": 0, "full": 0,
             "reused_bytes": 0, "uploaded_bytes": 0, "freed_bytes": 0}
    old_total = sum(int(a.nbytes) for a in old_arrays.values())
    new_total = sum(int(a.nbytes) for a in new_arrays.values())
    stats["freed_bytes"] = max(0, old_total - new_total)
    arrays = {}
    for name in _ARRAY_FIELDS:
        old_np, new_np = old_arrays[name], new_arrays[name]
        old_dev = getattr(prev_dev, name)
        if (old_np.shape == new_np.shape and old_dev.shape == old_np.shape
                and np.array_equal(old_np, new_np)):
            arrays[name] = old_dev
            stats["reused"] += 1
            stats["reused_bytes"] += int(new_np.nbytes)
        elif (old_np.shape[0] < new_np.shape[0]
              and old_dev.shape == old_np.shape
              and np.array_equal(old_np, new_np[:old_np.shape[0]])):
            suffix = jnp.asarray(
                np.ascontiguousarray(new_np[old_np.shape[0]:]))
            arrays[name] = jnp.concatenate([old_dev, suffix])
            stats["suffix"] += 1
            stats["reused_bytes"] += int(old_np.nbytes)
            stats["uploaded_bytes"] += int(suffix.nbytes)
        else:
            arrays[name] = jnp.asarray(new_np)
            stats["full"] += 1
            stats["uploaded_bytes"] += int(new_np.nbytes)
    return DeviceIndex(**meta, **arrays), stats


def stratum_device(dix: DeviceIndex, sx: StratifiedPECB,
                   k: int) -> DeviceIndex:
    """Carve ONE stratum's block out of a fused stratified device mirror.

    A single-k program (the window sweep) pays propagation cost on every
    forest node of the mirror it runs against — on the fused mixed-k
    mirror, every stratum's nodes, a |K|-fold tax for a launch that can
    only ever touch one stratum. This slices the ``[knode_ptr[ki],
    knode_ptr[ki+1])`` node block plus its entry / vertex-entry / version
    segments into a standalone per-k :class:`DeviceIndex` (a handful of
    eager device slices, no host round trip), with forest-node links
    rebased into the block's local id space. Array-for-array equal to
    ``to_device(sx.slice_k(k))`` (test-asserted); the static
    ``max_*_entries`` meta keeps the fused mirror's values — a valid
    upper bound costing at most a few extra binary-search steps.
    """
    ki = sx.k_index(k)
    n = dix.n
    nlo, nhi = int(sx.knode_ptr[ki]), int(sx.knode_ptr[ki + 1])
    elo, ehi = int(sx.kent_ptr[ki]), int(sx.kent_ptr[ki + 1])
    vlo, vhi = int(sx.kvent_ptr[ki]), int(sx.kvent_ptr[ki + 1])
    st = sx.strata
    slo, shi = ((int(st.kptr[ki]), int(st.kptr[ki + 1]))
                if st is not None else (0, 0))
    pad0 = jnp.zeros((1,), jnp.int32)
    padn = jnp.full((1,), NONE, jnp.int32)

    def links(a):
        seg = a[elo:ehi]
        # node links are global forest ids; -1 stays the no-link sentinel
        return jnp.where(seg >= 0, seg - nlo, seg) if nlo else seg

    has_ent, has_vent, has_ver = ehi > elo, vhi > vlo, shi > slo
    vent_node = dix.vent_node[vlo:vhi]
    if nlo and has_vent:
        vent_node = jnp.where(vent_node >= 0, vent_node - nlo, vent_node)
    return DeviceIndex(
        n=n, t_max=dix.t_max,
        node_u=dix.node_u[nlo:nhi],
        node_v=dix.node_v[nlo:nhi],
        node_ct=dix.node_ct[nlo:nhi],
        live_from=dix.live_from[nlo:nhi],
        live_to=dix.live_to[nlo:nhi],
        row_ptr=dix.row_ptr[nlo:nhi + 1] - elo,
        ent_ts=dix.ent_ts[elo:ehi] if has_ent else pad0,
        ent_left=links(dix.ent_left) if has_ent else padn,
        ent_right=links(dix.ent_right) if has_ent else padn,
        ent_parent=links(dix.ent_parent) if has_ent else padn,
        vrow_ptr=dix.vrow_ptr[ki * n:(ki + 1) * n + 1] - vlo,
        vent_ts=dix.vent_ts[vlo:vhi] if has_vent else pad0,
        vent_node=vent_node if has_vent else padn,
        ver_ts_from=(dix.ver_ts_from[slo:shi] if has_ver
                     else jnp.ones((1,), jnp.int32)),
        ver_ts_to=dix.ver_ts_to[slo:shi] if has_ver else pad0,
        ver_ct=dix.ver_ct[slo:shi] if has_ver else pad0,
        ver_src=dix.ver_src[slo:shi] if has_ver else pad0,
        ver_k=dix.ver_k[slo:shi] if has_ver else pad0,
        max_node_entries=dix.max_node_entries,
        max_vert_entries=dix.max_vert_entries,
        num_versions=shi - slo,
    )


def _lower_bound(ts_arr: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                 target: jnp.ndarray, steps: int) -> jnp.ndarray:
    """Vectorized lower_bound: smallest i in [lo, hi) with ts_arr[i] >= target.

    All of ``lo``/``hi``/``target`` share a broadcastable shape; returns hi
    when no element qualifies. ``steps`` must be >= ceil(log2(max segment)).
    """
    size = ts_arr.shape[0]
    for _ in range(max(steps, 1)):
        mid = (lo + hi) // 2
        mid_c = jnp.clip(mid, 0, size - 1)
        go_right = (ts_arr[mid_c] < target) & (mid < hi)
        lo = jnp.where(go_right & (lo < hi), mid + 1, lo)
        hi = jnp.where((~go_right) & (lo < hi), mid, hi)
    return lo


def _entry_steps(dix: DeviceIndex) -> tuple[int, int]:
    vsteps = int(np.ceil(np.log2(max(dix.max_vert_entries, 1) + 1))) + 1
    nsteps = int(np.ceil(np.log2(max(dix.max_node_entries, 1) + 1))) + 1
    return vsteps, nsteps


def _entry_nodes(dix: DeviceIndex, vlo, vhi, ts, te):
    """Resolve entry nodes given per-query vertex CSR bounds (Alg 1 line 3).
    Returns (e0_ok, e0c): validity mask + clipped entry node ids."""
    vsteps, _ = _entry_steps(dix)
    N = dix.num_nodes
    vi = _lower_bound(dix.vent_ts, vlo, vhi, ts, vsteps)
    has_entry = vi < vhi
    e0 = jnp.where(has_entry,
                   dix.vent_node[jnp.clip(vi, 0, dix.vent_ts.shape[0] - 1)],
                   NONE)
    e0_ok = has_entry & (e0 >= 0)
    e0c = jnp.clip(e0, 0, N - 1)
    e0_ok = e0_ok & (dix.node_ct[e0c] <= te)
    return e0_ok, e0c


def _component_masks(dix: DeviceIndex, e0_ok, e0c, ts, te) -> jnp.ndarray:
    """Steps 2-5: per-(query, node) link resolution, activity masking,
    min-label propagation, membership collection.

    Returns the ``bool[B, n]`` vertex mask: forest-node membership is the
    converged-label derivation (``label[x] == label[entry_b]``, masked by
    activity), scattered to the member nodes' endpoints."""
    B = ts.shape[0]
    N = dix.num_nodes
    n = dix.n
    _, nsteps = _entry_steps(dix)

    # -- 2. per-(query, node) link resolution ---------------------------
    lo = jnp.broadcast_to(dix.row_ptr[:-1][None, :], (B, N))
    hi = jnp.broadcast_to(dix.row_ptr[1:][None, :], (B, N))
    idx = _lower_bound(dix.ent_ts, lo, hi, ts[:, None], nsteps)
    idx_c = jnp.clip(idx, 0, dix.ent_ts.shape[0] - 1)
    link_l = dix.ent_left[idx_c]
    link_r = dix.ent_right[idx_c]
    link_p = dix.ent_parent[idx_c]

    # -- 3. per-(query, node) activity ----------------------------------
    active = (
        (dix.live_from[None, :] <= ts[:, None])
        & (ts[:, None] <= dix.live_to[None, :])
        & (dix.node_ct[None, :] <= te[:, None])
    )

    def neighbor_labels(labels, link):
        ok = (link >= 0) & active
        linkc = jnp.clip(link, 0, N - 1)
        nb = jnp.take_along_axis(labels, linkc, axis=1)
        nb_active = jnp.take_along_axis(active, linkc, axis=1)
        return jnp.where(ok & nb_active, nb, N)

    # -- 4. min-label propagation with pointer jumping -------------------
    labels0 = jnp.where(active, jnp.arange(N, dtype=jnp.int32)[None, :], jnp.int32(N))

    def body(state):
        labels, _ = state
        cand = jnp.minimum(
            jnp.minimum(neighbor_labels(labels, link_l), neighbor_labels(labels, link_r)),
            neighbor_labels(labels, link_p),
        )
        new = jnp.minimum(labels, cand)
        # pointer jumping: label <- label[label] (min is monotone-safe)
        jc = jnp.clip(new, 0, N - 1)
        jumped = jnp.where(new < N, jnp.take_along_axis(new, jc, axis=1), new)
        new = jnp.minimum(new, jumped)
        return new, jnp.any(new != labels)

    labels, _ = jax.lax.while_loop(lambda s: s[1], body, (labels0, jnp.array(True)))

    # -- 5. membership: label[x] == label[entry_b], masked by activity ----
    root = jnp.take_along_axis(labels, e0c[:, None], axis=1)
    member = active & (labels == root) & e0_ok[:, None]

    out = jnp.zeros((B, n), jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None], (B, N))
    out = out.at[rows, jnp.broadcast_to(dix.node_u[None, :], (B, N))].max(member.astype(jnp.int32))
    out = out.at[rows, jnp.broadcast_to(dix.node_v[None, :], (B, N))].max(member.astype(jnp.int32))
    return out.astype(bool)


def _version_member(dix: DeviceIndex, vertex_mask, ts, te):
    """bool[B, V] core-time version membership: version j is a member edge
    for query b iff its record covers ``ts_b``, ``ct_j <= te_b`` and its
    src endpoint is in the component (one gather over the vertex mask)."""
    src_in = vertex_mask[:, dix.ver_src]
    return (
        (dix.ver_ts_from[None, :] <= ts[:, None])
        & (ts[:, None] <= dix.ver_ts_to[None, :])
        & (dix.ver_ct[None, :] <= te[:, None])
        & src_in
    )


@jax.jit
def batch_query(dix: DeviceIndex, u: jnp.ndarray, ts: jnp.ndarray,
                te: jnp.ndarray) -> jnp.ndarray:
    """bool[B, n] vertex-membership of each query's k-core component."""
    B = u.shape[0]
    if dix.num_nodes == 0:
        return jnp.zeros((B, dix.n), bool)
    e0_ok, e0c = _entry_nodes(dix, dix.vrow_ptr[u], dix.vrow_ptr[u + 1], ts, te)
    return _component_masks(dix, e0_ok, e0c, ts, te)


@jax.jit
def batch_query_full(dix: DeviceIndex, u: jnp.ndarray, ts: jnp.ndarray,
                     te: jnp.ndarray):
    """(bool[B, n] vertex mask, bool[B, V] version-membership mask).

    The version mask is the device-side EDGES/SUBGRAPH payload: exactly the
    member edges of each query's component (oracle-exact; see module doc).
    """
    B = u.shape[0]
    if dix.num_nodes == 0:
        return (jnp.zeros((B, dix.n), bool),
                jnp.zeros((B, dix.ver_src.shape[0]), bool))
    e0_ok, e0c = _entry_nodes(dix, dix.vrow_ptr[u], dix.vrow_ptr[u + 1], ts, te)
    vmask = _component_masks(dix, e0_ok, e0c, ts, te)
    return vmask, _version_member(dix, vmask, ts, te)


@jax.jit
def batch_query_full_mixed(dix: DeviceIndex, slot: jnp.ndarray,
                           ts: jnp.ndarray, te: jnp.ndarray,
                           kq: jnp.ndarray):
    """Mixed-k batch against a stratified :class:`DeviceIndex`: one
    compiled program, per-query k as a device operand.

    ``slot`` is the per-query entry slot ``k_index(k) * n + u`` (computed
    host-side from the :class:`StratifiedPECB` handle; strata are
    link-disjoint so propagation needs no k mask) and ``kq`` the per-query
    k filtering the shared version arrays for the EDGES/SUBGRAPH payload.
    Returns ``(bool[B, n] vertex mask, bool[B, V] version mask)``.
    """
    B = slot.shape[0]
    if dix.num_nodes == 0:
        return (jnp.zeros((B, dix.n), bool),
                jnp.zeros((B, dix.ver_src.shape[0]), bool))
    e0_ok, e0c = _entry_nodes(dix, dix.vrow_ptr[slot],
                              dix.vrow_ptr[slot + 1], ts, te)
    vmask = _component_masks(dix, e0_ok, e0c, ts, te)
    vermask = (_version_member(dix, vmask, ts, te)
               & (dix.ver_k[None, :] == kq[:, None]))
    return vmask, vermask


def mixed_slots(sx: StratifiedPECB,
                queries: list[tuple[int, int]]) -> np.ndarray:
    """Host-side slot computation for a mixed-k batch: ``(u, k) ->
    k_index(k) * n + u``. Raises ``KeyError`` for an unsupported k — the
    serving planner short-circuits those before batching."""
    # int64 math first: k_index*n + u walks the fused slot space, which
    # outgrows int32 long before any single stratum does
    slots = np.asarray([sx.k_index(k) * sx.n + u for (u, k) in queries],
                       np.int64)
    return _i32(slots, "mixed-k entry slots")


def batch_query_mixed_np(sx: StratifiedPECB,
                         queries: list[tuple[int, int, int, int]]) -> list[set[int]]:
    """Host wrapper: mixed-k ``(u, ts, te, k)`` batch -> vertex sets
    (tests/benches)."""
    dix = to_device(sx)
    slot = jnp.asarray(mixed_slots(sx, [(u, k) for (u, _, _, k) in queries]))
    ts = jnp.asarray([q[1] for q in queries], jnp.int32)
    te = jnp.asarray([q[2] for q in queries], jnp.int32)
    kq = jnp.asarray([q[3] for q in queries], jnp.int32)
    vmask, _ = batch_query_full_mixed(dix, slot, ts, te, kq)
    mask = np.asarray(vmask)
    return [set(np.nonzero(row)[0].tolist()) for row in mask]


def batch_query_mixed_edges_np(sx: StratifiedPECB,
                               queries: list[tuple[int, int, int, int]]) -> list[set[int]]:
    """Host wrapper: mixed-k ``(u, ts, te, k)`` batch -> member *edge id*
    sets (tests/benches)."""
    if sx.strata is None:
        raise ValueError("index has no version store")
    dix = to_device(sx)
    slot = jnp.asarray(mixed_slots(sx, [(u, k) for (u, _, _, k) in queries]))
    ts = jnp.asarray([q[1] for q in queries], jnp.int32)
    te = jnp.asarray([q[2] for q in queries], jnp.int32)
    kq = jnp.asarray([q[3] for q in queries], jnp.int32)
    _, vermask = batch_query_full_mixed(dix, slot, ts, te, kq)
    vermask = np.asarray(vermask)[:, :dix.num_versions]
    eid = sx.strata.edge_id
    return [set(eid[np.nonzero(row)[0]].tolist()) for row in vermask]


@jax.jit
def window_sweep(dix: DeviceIndex, u: jnp.ndarray, ts: jnp.ndarray,
                 te: jnp.ndarray) -> jnp.ndarray:
    """bool[W, n] vertex masks for ONE vertex over W windows, one launch.

    ``u`` is a scalar: the vertex's entry segment ``[vrow_ptr[u],
    vrow_ptr[u+1])`` is resolved once and shared by every window — the
    sweep never re-gathers per-query CSR bounds the way ``batch_query``
    must for a heterogeneous batch.
    """
    W = ts.shape[0]
    if dix.num_nodes == 0:
        return jnp.zeros((W, dix.n), bool)
    vlo = jnp.broadcast_to(dix.vrow_ptr[u], (W,))
    vhi = jnp.broadcast_to(dix.vrow_ptr[u + 1], (W,))
    e0_ok, e0c = _entry_nodes(dix, vlo, vhi, ts, te)
    return _component_masks(dix, e0_ok, e0c, ts, te)


def batch_query_np(index: PECBIndex, queries: list[tuple[int, int, int]]) -> list[set[int]]:
    """Host convenience wrapper returning vertex sets (for tests/benches)."""
    dix = to_device(index)
    u = jnp.asarray([q[0] for q in queries], jnp.int32)
    ts = jnp.asarray([q[1] for q in queries], jnp.int32)
    te = jnp.asarray([q[2] for q in queries], jnp.int32)
    mask = np.asarray(batch_query(dix, u, ts, te))
    return [set(np.nonzero(row)[0].tolist()) for row in mask]


def batch_query_edges_np(index: PECBIndex,
                         queries: list[tuple[int, int, int]]) -> list[set[int]]:
    """Host wrapper over :func:`batch_query_full` returning per-query member
    *edge id* sets (for tests/benches)."""
    dix = to_device(index)
    store = index.versions
    if store is None:
        raise ValueError("index has no version store")
    u = jnp.asarray([q[0] for q in queries], jnp.int32)
    ts = jnp.asarray([q[1] for q in queries], jnp.int32)
    te = jnp.asarray([q[2] for q in queries], jnp.int32)
    _, vermask = batch_query_full(dix, u, ts, te)
    vermask = np.asarray(vermask)[:, :dix.num_versions]
    return [set(store.edge_id[np.nonzero(row)[0]].tolist()) for row in vermask]
