"""CTMSF-Index — the paper's vertex-centric baseline (§6).

Materialises the CT-MSF directly: each graph vertex stores the list of its
incident MSF edges, and writes a *new full list* whenever the list differs
from the previous start time. Vertex degree in a CT-MSF is unbounded, which
is exactly the redundancy the ECB forest removes — high-degree vertices
re-write long lists on every change. Index size accounting (``nbytes``)
charges every stored list in full, as the paper's Figure 4 does.

The MSF evolution itself is shared with the PECB builder (identical MSFs by
rank uniqueness), so construction cost is near-identical — matching the
paper's observation that the two build times coincide (§6.2).
"""

from __future__ import annotations

import bisect
import warnings

import numpy as np

from .core_time import CoreTimeTable, edge_core_times
from .ecb_forest import NONE, IncrementalBuilder
from .query_api import ComponentBackend, VersionStore
from .temporal_graph import TemporalGraph


class _VertexCentricBuilder(IncrementalBuilder):
    """Taps the shared MSF maintenance to snapshot per-vertex lists."""

    def __init__(self, g, tab):
        super().__init__(g, tab)
        # per-vertex list of (ts, tuple_of_node_ids) in build (desc-ts) order
        self.vlists: list[list[tuple]] = [[] for _ in range(g.n)]

    def flush(self, ts: int):
        for vert in self._dirty_verts:
            cur = tuple(self._inc_node[vert])
            ent = self.vlists[vert]
            if not ent or ent[-1][1] != cur:
                ent.append((ts, cur))
        super().flush(ts)


class CTMSFIndex(ComponentBackend):
    backend_name = "ctmsf"

    def __init__(self, g: TemporalGraph, k: int, tab: CoreTimeTable | None = None):
        self.g = g
        self.k = k
        tab = tab if tab is not None else edge_core_times(g, k)
        self.versions = VersionStore.from_table(g, k, tab)  # v2 surface
        b = _VertexCentricBuilder(g, tab).run()
        N = b.num_nodes
        self.node_u = np.asarray(b.n_u[:N], np.int32)
        self.node_v = np.asarray(b.n_v[:N], np.int32)
        self.node_ct = np.asarray(b.n_ct[:N], np.int32)
        # ascending-ts order for binary search
        self.vlists = [ent[::-1] for ent in b.vlists]

    # -- size accounting --------------------------------------------------
    def nbytes(self) -> int:
        total = (self.node_u.nbytes + self.node_v.nbytes + self.node_ct.nbytes)
        for ent in self.vlists:
            for (_, lst) in ent:
                total += 4 + 4 * len(lst)   # ts key + node ids
        return total

    # -- query (vertex-centric DFS over the CT-MSF) ------------------------
    def _list_at(self, vert: int, ts: int) -> tuple:
        ent = self.vlists[vert]
        i = bisect.bisect_left(ent, (ts, ()))
        if i == len(ent):
            return ()
        return ent[i][1]

    def query(self, u: int, ts: int, te: int) -> set[int]:
        """Deprecated positional shim; prefer ``answer(TCCSQuery(...))``.
        Emits :class:`DeprecationWarning`."""
        warnings.warn(
            "CTMSFIndex.query(u, ts, te) is deprecated; use "
            "answer(TCCSQuery(u, ts, te, k))",
            DeprecationWarning, stacklevel=2)
        return self._component_vertices(u, ts, te)

    def _component_vertices(self, u: int, ts: int, te: int) -> set[int]:
        first = self._list_at(u, ts)
        if not first or self.node_ct[first[0]] > te:
            return set()
        result: set[int] = set()
        seen_v: set[int] = set()
        stack = [u]
        while stack:
            x = stack.pop()
            if x in seen_v:
                continue
            seen_v.add(x)
            lst = self._list_at(x, ts)
            joined = False
            for node in lst:
                if self.node_ct[node] > te:
                    continue
                joined = True
                for y in (int(self.node_u[node]), int(self.node_v[node])):
                    if y not in seen_v:
                        stack.append(y)
            if joined or x == u:
                result.add(x)
        # u itself is only in the component if it had a valid incident edge
        if not any(self.node_ct[e] <= te for e in first):
            return set()
        return result
