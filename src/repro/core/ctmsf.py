"""CT-MSF (paper Def 4.6): minimum spanning forest under core-time weights.

Two constructions:

* :func:`kruskal_msf` — host oracle. Union-find over edges in ascending rank
  ``(ct, edge_id)``; the rank total order makes the MSF unique, which is what
  lets every structure in this repo (ECB forest, CTMSF baseline, Borůvka)
  agree edge-for-edge.

* :func:`boruvka_msf` — the TPU-facing adaptation (DESIGN.md §3). Kruskal is
  pointer-sequential; Borůvka is O(log n) data-parallel rounds of
  per-component ``segment_min`` + pointer-jumping hook/compress, all jnp.
  With unique weights Borůvka selects exactly the Kruskal forest, so the two
  are tested for array equality.

Weights are packed as ``ct * (m+1) + edge_id`` in int64 so that the paper's
tie-break on edge id is preserved inside a single scalar key.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
# Host oracle
# ----------------------------------------------------------------------

def kruskal_msf(u: np.ndarray, v: np.ndarray, ct: np.ndarray, n: int) -> np.ndarray:
    """bool[m] mask of MSF edges; rank = (ct, index) ascending."""
    m = u.shape[0]
    order = np.lexsort((np.arange(m), ct))
    parent = np.arange(n, dtype=np.int64)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    keep = np.zeros(m, bool)
    for i in order:
        ra, rb = find(int(u[i])), find(int(v[i]))
        if ra != rb:
            parent[ra] = rb
            keep[i] = True
    return keep


# ----------------------------------------------------------------------
# Borůvka in jnp (device path)
# ----------------------------------------------------------------------

def _pack_weight(ct: jnp.ndarray, m: int) -> jnp.ndarray:
    # int32 packing (JAX x64 is off by default): requires (max_ct+1)*(m+1)
    # < 2**31, asserted by the host wrapper; ample for every bench workload.
    eid = jnp.arange(ct.shape[0], dtype=jnp.int32)
    return ct.astype(jnp.int32) * jnp.int32(m + 1) + eid


def boruvka_msf(u: jnp.ndarray, v: jnp.ndarray, ct: jnp.ndarray, n: int) -> jnp.ndarray:
    """bool[m] MSF mask, pure jnp (jit-able; static n, m).

    Each round: every component picks its minimum-weight outgoing edge
    (segment_min over both endpoints' component labels), the picked edges are
    committed to the forest, components hook along them, and labels are
    compressed by pointer jumping. Unique weights guarantee no cycles among
    picks except mutual pairs, which the standard (min-endpoint wins) rule
    breaks.
    """
    m = int(u.shape[0])
    if m == 0:
        return jnp.zeros((0,), bool)
    w = _pack_weight(ct, m)
    INF = jnp.int32(np.iinfo(np.int32).max)

    def round_body(state):
        label, in_msf, _changed = state
        cu, cv = label[u], label[v]
        cross = cu != cv
        ew = jnp.where(cross, w, INF)
        # per-component minimum outgoing weight (weights are unique per edge)
        best_u = jax.ops.segment_min(ew, cu, num_segments=n)
        best_v = jax.ops.segment_min(ew, cv, num_segments=n)
        best = jnp.minimum(best_u, best_v)              # [n] per-component min weight
        has = best < INF
        # an edge joins the forest if it is the best of either endpoint's component
        is_best = cross & ((ew == best[cu]) | (ew == best[cv]))
        in_msf = in_msf | is_best
        # hook: component -> the other endpoint's component along its best edge
        partner = jnp.full((n,), -1, jnp.int32)
        bu = jnp.where(ew == best[cu], cv, -1)
        bv = jnp.where(ew == best[cv], cu, -1)
        partner = partner.at[cu].max(bu)
        partner = partner.at[cv].max(bv)
        partner = jnp.where(partner >= 0, partner, jnp.arange(n, dtype=jnp.int32))
        # mutual-pair tie break: if partner[partner[c]] == c, smaller id wins as root
        par = jnp.where(has, partner, jnp.arange(n, dtype=jnp.int32))
        mutual = par[par] == jnp.arange(n, dtype=jnp.int32)
        par = jnp.where(mutual & (jnp.arange(n, dtype=jnp.int32) < par), jnp.arange(n, dtype=jnp.int32), par)
        # pointer jumping until converged (log n doublings suffice)
        def jump(_, p):
            return p[p]
        par = jax.lax.fori_loop(0, int(np.ceil(np.log2(max(n, 2)))) + 1, jump, par)
        new_label = par[label]
        changed = jnp.any(new_label != label)
        return new_label, in_msf, changed

    def cond(state):
        return state[2]

    label0 = jnp.arange(n, dtype=jnp.int32)
    in0 = jnp.zeros((m,), bool)
    label, in_msf, _ = jax.lax.while_loop(cond, round_body, (label0, in0, jnp.array(True)))
    return in_msf


def boruvka_msf_np(u: np.ndarray, v: np.ndarray, ct: np.ndarray, n: int) -> np.ndarray:
    """Convenience host wrapper (casts + device round-trip)."""
    if u.shape[0] == 0:
        return np.zeros(0, bool)
    if (int(ct.max()) + 1) * (u.shape[0] + 1) >= 2**31:
        raise OverflowError(
            "int32 weight overflow: (max core time + 1) * (edges + 1) = "
            f"{(int(ct.max()) + 1) * (u.shape[0] + 1)} >= 2**31")
    fn = jax.jit(boruvka_msf, static_argnums=(3,))
    return np.asarray(fn(jnp.asarray(u), jnp.asarray(v), jnp.asarray(ct), int(n)))


def ct_msf_at(g, tab, ts: int) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(u, v, ct, msf_mask) of the CT-MSF for start time ``ts`` (host oracle).

    Versions active at ts with finite core times are the MSF candidate edges.
    """
    from .ecb_forest import active_versions

    e_ids, cts = active_versions(tab, ts)
    u = g.src[e_ids].astype(np.int64)
    v = g.dst[e_ids].astype(np.int64)
    keep = kruskal_msf(u, v, cts.astype(np.int64), g.n)
    return u, v, cts.astype(np.int64), keep
