"""TCCS Query API v2: the typed query surface every backend speaks
(DESIGN.md §8).

The paper's motivating applications (contact tracing, fault diagnosis,
financial forensics — §1) need more than the vertex set Algorithm 1
returns: they want the *induced temporal subgraph* of the k-core component
and its evolution over sliding windows. Before this module every layer
spoke a positional ``(u, ts, te) -> set[int]`` dialect; now there is one
spec/result pair shared by the three index backends (PECB, EF, CTMSF), the
serving engine, the device plane and the tests:

* :class:`TCCSQuery` — a frozen, hashable spec ``(u, ts, te, k, mode)``
  with explicit validation (:meth:`TCCSQuery.validate` raises
  :class:`InvalidQueryError`; nothing silently returns empty any more) and
  canonicalization (:meth:`TCCSQuery.canonical` clamps the window to
  ``[1, t_max]`` and folds every empty window onto one marker, so
  equivalent queries share a single cache key).
* :class:`ResultMode` — VERTICES (the classic answer), EDGES (the member
  temporal edges of the component, as version records ``u/v/t/ct/edge_id``),
  SUBGRAPH (an induced :class:`TemporalGraph` snapshot), COUNT (sizes only).
* :class:`TCCSResult` — vertices plus the mode-dependent payload and
  per-query :class:`Provenance` (route, index key, stage timings).
* :class:`TCCSBackend` — the protocol all three index classes implement
  (``answer(TCCSQuery) -> TCCSResult``); :class:`ComponentBackend` is the
  shared mixin that turns a backend's native component routine
  (``_component_vertices``) plus its :class:`VersionStore` into the full
  typed surface.
* :class:`WindowSweep` — one vertex queried over many sliding windows (the
  contact-tracing trajectory query); the device plane answers a whole sweep
  in one launch (``batch_query.window_sweep``).

Edge membership is exact, not approximate: version ``j`` of edge
``edge_id[j]`` is in the temporal k-core of ``[ts, te]`` iff
``ts_from[j] <= ts <= ts_to[j] and ct[j] <= te`` (the core-time
characterization the property suite asserts), and an edge of the core
belongs to u's component iff either endpoint does. The brute-force oracle
for this is :func:`repro.core.kcore.tccs_oracle_edges`.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Iterable, Protocol, Sequence, runtime_checkable

import numpy as np

from .temporal_graph import TemporalGraph


class InvalidQueryError(ValueError):
    """A query spec violates the API contract (``ts > te``, out-of-range
    ``u``, ``k < 2``, wrong k for the index, bad mode). Raised eagerly at
    the API boundary instead of silently answering the empty set."""


class ResultMode(enum.Enum):
    VERTICES = "vertices"
    EDGES = "edges"
    SUBGRAPH = "subgraph"
    COUNT = "count"


#: Canonical empty window: every window that can match nothing folds onto
#: this one (ts, te) pair so all such queries share one cache key.
EMPTY_WINDOW = (1, 0)


@dataclasses.dataclass(frozen=True)
class TCCSQuery:
    """One TCCS query: the temporal k-core component of ``u`` in ``[ts, te]``.

    Plain data — construction never raises (the serving engine's legacy
    shims build lenient specs from raw ints). :meth:`validate` is the v2
    boundary check; :meth:`canonical` the cache-key normalizer.
    """

    u: int
    ts: int
    te: int
    k: int
    mode: ResultMode = ResultMode.VERTICES

    def __post_init__(self):
        object.__setattr__(self, "u", int(self.u))
        object.__setattr__(self, "ts", int(self.ts))
        object.__setattr__(self, "te", int(self.te))
        object.__setattr__(self, "k", int(self.k))
        if isinstance(self.mode, str):
            object.__setattr__(self, "mode", ResultMode(self.mode))

    @property
    def is_empty_window(self) -> bool:
        return self.ts > self.te

    def validate(self, n: int | None = None,
                 t_max: int | None = None) -> "TCCSQuery":
        """Raise :class:`InvalidQueryError` on a malformed spec.

        ``n`` enables the vertex-range check (skipped when the graph is not
        yet resolvable, e.g. a cold registry key — the backend re-validates
        at answer time). A window beyond ``t_max`` is *valid but empty*
        (canonicalization folds it), only ``ts > te`` is a caller error.
        """
        if not isinstance(self.mode, ResultMode):
            raise InvalidQueryError(f"mode must be a ResultMode, got {self.mode!r}")
        if self.k < 2:
            raise InvalidQueryError(f"k must be >= 2, got k={self.k}")
        if self.ts > self.te and (self.ts, self.te) != EMPTY_WINDOW:
            raise InvalidQueryError(
                f"window [{self.ts}, {self.te}] has ts > te")
        if n is not None and not 0 <= self.u < n:
            raise InvalidQueryError(
                f"vertex u={self.u} out of range [0, {n})")
        return self

    def canonical(self, t_max: int) -> "TCCSQuery":
        """Clamp the window to ``[1, t_max]``; fold empty windows onto
        :data:`EMPTY_WINDOW`. Equivalent queries canonicalize identically,
        so they share one cache key and one device-batch lane.

        An empty graph (``t_max == 0``) clamps every window to ``ts > te``
        and therefore folds it onto the marker too — the result is always
        either a valid non-empty window or :data:`EMPTY_WINDOW`, never an
        un-marked invalid clamp like a raw ``[1, 0]``."""
        ts, te = max(self.ts, 1), min(self.te, t_max)
        if ts > te:
            ts, te = EMPTY_WINDOW
        if (ts, te) == (self.ts, self.te):
            return self
        return dataclasses.replace(self, ts=ts, te=te)

    def cache_key(self) -> tuple:
        return (self.u, self.ts, self.te, self.k, self.mode.value)


@dataclasses.dataclass(frozen=True)
class WindowSweep:
    """One vertex, many windows: the trajectory form of TCCS.

    The device plane answers all ``windows`` in one launch
    (``batch_query.window_sweep``), sharing the per-vertex entry-segment
    resolution across windows — this is the contact-tracing incubation
    sweep served at device batch rates.
    """

    u: int
    k: int
    windows: tuple
    mode: ResultMode = ResultMode.VERTICES

    def __post_init__(self):
        object.__setattr__(self, "u", int(self.u))
        object.__setattr__(self, "k", int(self.k))
        object.__setattr__(
            self, "windows",
            tuple((int(a), int(b)) for (a, b) in self.windows))
        if isinstance(self.mode, str):
            object.__setattr__(self, "mode", ResultMode(self.mode))

    @property
    def num_windows(self) -> int:
        return len(self.windows)

    def specs(self) -> list[TCCSQuery]:
        return [TCCSQuery(self.u, ts, te, self.k, self.mode)
                for (ts, te) in self.windows]


# ----------------------------------------------------------------------
# Results
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class EdgeSet:
    """Member temporal edges of one component, SoA (``u/v/t/ct/edge_id``).

    ``ct`` is the per-version core time at the query's start time — the
    ``node_ct`` flavour of the forest tables, but over *all* member edges
    of the component, not only the spanning subset.
    """

    u: np.ndarray         # int32[M]
    v: np.ndarray         # int32[M]
    t: np.ndarray         # int32[M]  original edge timestamps
    ct: np.ndarray        # int32[M]  core time at the query's ts
    edge_id: np.ndarray   # int32[M]  ids into the source TemporalGraph

    @classmethod
    def empty(cls) -> "EdgeSet":
        z = np.zeros(0, np.int32)
        return cls(z, z.copy(), z.copy(), z.copy(), z.copy())

    @property
    def m(self) -> int:
        return int(self.edge_id.shape[0])

    def edge_ids(self) -> frozenset:
        return frozenset(self.edge_id.tolist())

    def vertex_projection(self) -> frozenset:
        return frozenset(np.union1d(self.u, self.v).tolist())


@dataclasses.dataclass(frozen=True)
class Provenance:
    """Where and how a result was computed (per-query observability).

    ``trace_id``/``span_id`` link the result back to its query-lifecycle
    span tree in the engine's tracer (DESIGN.md §11.3): any
    :class:`TCCSResult` can be joined against the exported Chrome trace.
    Excluded from equality — two runs of the same query are the *same
    answer* with different traces."""

    route: str                       # host | device | sweep | cache | trivial
                                     # | disk (index promoted from the store)
    backend: str = ""                # pecb | ef | ctmsf | pecb-device | ...
    index_key: str | tuple | None = None  # workload key when engine-served
    batch_size: int = 1
    bucket: int | None = None        # padded device batch shape, if any
    timings: dict = dataclasses.field(default_factory=dict, compare=False)
    trace_id: str | None = dataclasses.field(default=None, compare=False)
    span_id: str | None = dataclasses.field(default=None, compare=False)


@dataclasses.dataclass(frozen=True, eq=False)
class TCCSResult:
    """A typed TCCS answer. ``vertices`` is always the component vertex set
    except in COUNT mode (sizes only); ``edges``/``subgraph`` are filled by
    mode. Results are immutable and cacheable; a cache hit is re-stamped
    with ``route="cache"`` provenance by the engine."""

    query: TCCSQuery                 # the canonical spec answered
    vertices: frozenset
    num_vertices: int
    num_edges: int | None = None
    edges: EdgeSet | None = None
    subgraph: TemporalGraph | None = None
    provenance: Provenance | None = None

    def __len__(self) -> int:
        return self.num_vertices


# ----------------------------------------------------------------------
# Version store: the shared edge-membership metadata
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class VersionStore:
    """Per-version membership metadata shared by all backends.

    Version ``j`` (edge ``edge_id[j]``) is in the temporal k-core of
    ``[ts, te]`` iff ``ts_from[j] <= ts <= ts_to[j]`` and ``ct[j] <= te``
    (the core-time characterization, asserted by the property suite), and
    it belongs to u's component iff its ``src`` endpoint does. This is what
    lets every backend — and the device plane — answer EDGES/SUBGRAPH
    modes exactly, not just the spanning-forest subset.

    Not charged to any index's ``nbytes()``: it is the core-time table the
    construction already produced, carried through for the query surface;
    the paper's index-size comparison (Fig 4) stays undistorted.
    """

    n: int
    t_max: int
    k: int
    edge_id: np.ndarray   # int32[V]
    ts_from: np.ndarray   # int32[V]
    ts_to: np.ndarray     # int32[V]
    ct: np.ndarray        # int32[V]
    src: np.ndarray       # int32[V]  = g.src[edge_id]
    dst: np.ndarray       # int32[V]  = g.dst[edge_id]
    t: np.ndarray         # int32[V]  = g.t[edge_id]

    @classmethod
    def from_table(cls, g: TemporalGraph, k: int, tab) -> "VersionStore":
        eid = np.asarray(tab.edge_id, np.int32)
        return cls(
            n=g.n, t_max=g.t_max, k=int(k),
            edge_id=eid,
            ts_from=np.asarray(tab.ts_from, np.int32),
            ts_to=np.asarray(tab.ts_to, np.int32),
            ct=np.asarray(tab.ct, np.int32),
            src=g.src[eid].astype(np.int32),
            dst=g.dst[eid].astype(np.int32),
            t=g.t[eid].astype(np.int32),
        )

    @property
    def num_versions(self) -> int:
        return int(self.edge_id.shape[0])

    def __eq__(self, other) -> bool:
        """Structural equality over every array (the builder-purity tests
        compare whole indexes field by field)."""
        if not isinstance(other, VersionStore):
            return NotImplemented
        if (self.n, self.t_max, self.k) != (other.n, other.t_max, other.k):
            return False
        return all(np.array_equal(getattr(self, f), getattr(other, f))
                   for f in ("edge_id", "ts_from", "ts_to", "ct",
                             "src", "dst", "t"))

    def select(self, version_ids: np.ndarray) -> EdgeSet:
        """EdgeSet for explicit version indices (device-plane membership
        masks land here)."""
        ids = np.asarray(version_ids, np.int64)
        return EdgeSet(self.src[ids], self.dst[ids], self.t[ids],
                       self.ct[ids], self.edge_id[ids])

    def member_edges(self, vertices: Iterable[int] | np.ndarray,
                     ts: int, te: int) -> EdgeSet:
        """All member edges of the component given its vertex set (host
        route). ``vertices`` may be a set/iterable or a bool[n] mask."""
        if isinstance(vertices, np.ndarray) and vertices.dtype == bool:
            in_comp = vertices
        else:
            in_comp = np.zeros(self.n, bool)
            vs = np.fromiter((int(v) for v in vertices), np.int64,
                             count=len(vertices) if hasattr(vertices, "__len__") else -1)
            in_comp[vs] = True
        if self.num_versions == 0 or not in_comp.any():
            return EdgeSet.empty()
        m = ((self.ts_from <= ts) & (ts <= self.ts_to)
             & (self.ct <= te) & in_comp[self.src])
        return self.select(np.nonzero(m)[0])


# ----------------------------------------------------------------------
# Result assembly (shared by host backends and the serving planner)
# ----------------------------------------------------------------------

def build_result(cq: TCCSQuery, vertices: frozenset,
                 store: VersionStore | None,
                 provenance: Provenance | None = None, *,
                 edge_set: EdgeSet | None = None) -> TCCSResult:
    """Assemble a :class:`TCCSResult` for a canonical spec from the
    component vertex set, deriving the mode payload from ``store`` (or an
    explicit ``edge_set``, e.g. the device plane's membership mask)."""
    mode = cq.mode
    if mode is ResultMode.VERTICES:
        return TCCSResult(cq, vertices, len(vertices), provenance=provenance)
    if mode in (ResultMode.EDGES, ResultMode.SUBGRAPH):
        if edge_set is None:
            if store is None:
                raise InvalidQueryError(
                    f"{mode.value} mode needs a VersionStore-backed index")
            edge_set = (EdgeSet.empty() if not vertices else
                        store.member_edges(vertices, cq.ts, cq.te))
        if mode is ResultMode.EDGES:
            return TCCSResult(cq, vertices, len(vertices), edge_set.m,
                              edges=edge_set, provenance=provenance)
        n = store.n if store is not None else (max(vertices) + 1 if vertices else 0)
        sub = TemporalGraph.from_edges(
            n, zip(edge_set.u.tolist(), edge_set.v.tolist(),
                   edge_set.t.tolist()))
        return TCCSResult(cq, vertices, len(vertices), edge_set.m,
                          edges=edge_set, subgraph=sub, provenance=provenance)
    if mode is ResultMode.COUNT:
        return TCCSResult(cq, frozenset(), len(vertices),
                          provenance=provenance)
    raise InvalidQueryError(f"unknown mode {mode!r}")


def empty_result(cq: TCCSQuery, n: int,
                 provenance: Provenance | None = None) -> TCCSResult:
    """The empty answer in the requested mode (trivial/short-circuit path:
    empty windows, lenient out-of-range vertices, cold empty forests)."""
    if cq.mode in (ResultMode.EDGES, ResultMode.SUBGRAPH):
        es = EdgeSet.empty()
        sub = (TemporalGraph.from_edges(n, [])
               if cq.mode is ResultMode.SUBGRAPH else None)
        return TCCSResult(cq, frozenset(), 0, 0, edges=es, subgraph=sub,
                          provenance=provenance)
    # VERTICES/COUNT carry no edge payload on any route: num_edges stays
    # None (COUNT is the *vertex* count; computing edges would cost the
    # EDGES path)
    return TCCSResult(cq, frozenset(), 0, provenance=provenance)


# ----------------------------------------------------------------------
# The backend protocol + shared mixin
# ----------------------------------------------------------------------

@runtime_checkable
class TCCSBackend(Protocol):
    """What every TCCS index speaks: one typed query surface. Implemented
    by PECBIndex, EFIndex and CTMSFIndex (via :class:`ComponentBackend`),
    so tests and benchmarks compare backends through one interface."""

    k: int

    def answer(self, q: TCCSQuery) -> TCCSResult: ...


class ComponentBackend:
    """Mixin: native component routine + VersionStore -> full v2 surface.

    Subclasses provide ``k``, ``versions`` (a :class:`VersionStore`),
    ``backend_name`` and ``_component_vertices(u, ts, te) -> set[int]``
    (their Algorithm-1-equivalent, assuming a validated canonical window).
    """

    backend_name: str = "backend"
    versions: VersionStore | None = None

    def _component_vertices(self, u: int, ts: int, te: int) -> set:
        raise NotImplementedError

    def answer(self, q: TCCSQuery) -> TCCSResult:
        store = self.versions
        if store is None:
            raise InvalidQueryError(
                f"{self.backend_name} index was built without a version "
                "store; rebuild it to use the v2 query surface")
        q.validate(n=store.n)
        if q.k != self.k:
            raise InvalidQueryError(
                f"query k={q.k} does not match this index (k={self.k})")
        cq = q.canonical(store.t_max)
        t0 = time.perf_counter()
        vertices = (frozenset() if cq.is_empty_window else
                    frozenset(self._component_vertices(cq.u, cq.ts, cq.te)))
        t1 = time.perf_counter()
        prov = Provenance(route="host", backend=self.backend_name,
                          timings={"component_s": t1 - t0})
        res = build_result(cq, vertices, store, prov)
        prov.timings["total_s"] = time.perf_counter() - t0
        return res

    def answer_many(self, specs: Sequence[TCCSQuery]) -> list[TCCSResult]:
        return [self.answer(q) for q in specs]
