"""PECB-Index (paper §4.1 Table 2, §4.2 Algorithm 1).

The incremental builder's per-node entry lists are packed into flat CSR
arrays so that (a) host queries are cache-friendly, (b) the same arrays ship
unchanged to the device for the batched query engine (``batch_query.py``),
and (c) index size accounting is exact (``nbytes``).

Entry resolution for a node at start time ``ts`` is the paper's binary
search: the entry with the smallest recorded start time >= ts (entries are
recorded while ts descends, only on change). Nodes/vertices whose earliest
recorded entry is below ``ts`` are not in the forest at ``ts``.

Query surface: the typed v2 API (``answer(TCCSQuery) -> TCCSResult``, via
:class:`repro.core.query_api.ComponentBackend`) is primary; ``query(u, ts,
te)`` remains as a thin deprecation shim over the same component routine.
The attached :class:`VersionStore` (the core-time table carried through
construction) powers the EDGES/SUBGRAPH modes; it is deliberately excluded
from ``nbytes()`` so the paper's index-size comparison stays undistorted.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .core_time import CoreTimeTable, edge_core_times
from .ecb_forest import NONE, ForestInvariantError, IncrementalBuilder
from .query_api import ComponentBackend, VersionStore
from .temporal_graph import TemporalGraph


@dataclasses.dataclass
class PECBIndex(ComponentBackend):
    n: int
    m: int
    t_max: int
    k: int
    # node (= edge version) table
    node_u: np.ndarray        # int32[N]
    node_v: np.ndarray        # int32[N]
    node_ct: np.ndarray       # int32[N]
    node_edge: np.ndarray     # int32[N]
    node_live_from: np.ndarray  # int32[N]  (first ts with node in forest)
    node_live_to: np.ndarray    # int32[N]  (last ts with node in forest)
    # node entries, CSR, per-node ascending ts
    row_ptr: np.ndarray       # int32[N+1]
    ent_ts: np.ndarray        # int32[E]
    ent_left: np.ndarray      # int32[E]
    ent_right: np.ndarray     # int32[E]
    ent_parent: np.ndarray    # int32[E]
    # per-vertex entry points, CSR, per-vertex ascending ts
    vrow_ptr: np.ndarray      # int32[n+1]
    vent_ts: np.ndarray       # int32[VE]
    vent_node: np.ndarray     # int32[VE]
    # v2 query surface: per-version membership metadata (EDGES/SUBGRAPH
    # modes); not index payload, excluded from nbytes()
    versions: VersionStore | None = None

    backend_name = "pecb"

    @property
    def num_nodes(self) -> int:
        return int(self.node_u.shape[0])

    def nbytes(self) -> int:
        return sum(
            a.nbytes
            for a in (
                self.node_u, self.node_v, self.node_ct, self.node_edge,
                self.node_live_from, self.node_live_to,
                self.row_ptr, self.ent_ts, self.ent_left, self.ent_right,
                self.ent_parent, self.vrow_ptr, self.vent_ts, self.vent_node,
            )
        )

    # -- entry resolution (the paper's per-node binary search) ----------
    def resolve(self, node: int, ts: int):
        lo, hi = self.row_ptr[node], self.row_ptr[node + 1]
        i = lo + np.searchsorted(self.ent_ts[lo:hi], ts, side="left")
        if i == hi:
            return None  # version not in the forest at this start time
        return int(self.ent_left[i]), int(self.ent_right[i]), int(self.ent_parent[i])

    def entry_node(self, vert: int, ts: int) -> int:
        lo, hi = self.vrow_ptr[vert], self.vrow_ptr[vert + 1]
        i = lo + np.searchsorted(self.vent_ts[lo:hi], ts, side="left")
        if i == hi:
            return NONE
        return int(self.vent_node[i])

    # -- Algorithm 1 -----------------------------------------------------
    def query(self, u: int, ts: int, te: int) -> set[int]:
        """All vertices of the temporal k-core component of u in [ts, te].

        .. deprecated:: kept as a thin shim over the v2 surface; prefer
           ``answer(TCCSQuery(u, ts, te, k))`` which validates, carries
           result modes and records provenance. Emits
           :class:`DeprecationWarning`.
        """
        warnings.warn(
            "PECBIndex.query(u, ts, te) is deprecated; use "
            "answer(TCCSQuery(u, ts, te, k))",
            DeprecationWarning, stacklevel=2)
        return self._component_vertices(u, ts, te)

    def _component_vertices(self, u: int, ts: int, te: int) -> set[int]:
        e0 = self.entry_node(u, ts)
        if e0 == NONE or self.node_ct[e0] > te:
            return set()
        result: set[int] = set()
        seen: set[int] = set()
        stack = [e0]
        while stack:
            e = stack.pop()
            if e in seen:
                continue
            seen.add(e)
            result.add(int(self.node_u[e]))
            result.add(int(self.node_v[e]))
            links = self.resolve(e, ts)
            if links is None:
                # A reachable node must be in the ts-forest; a bare assert
                # here would vanish under `python -O` and silently return a
                # truncated component.
                raise ForestInvariantError(
                    f"query ({u}, {ts}, {te}) reached node {e} outside the "
                    "ts-forest: corrupt index")
            for nb in links:
                if nb != NONE and nb not in seen and self.node_ct[nb] <= te:
                    stack.append(nb)
        return result


def _csr_sorted(ids, ts, cols, num_rows):
    """(row_ptr, sorted column arrays) for flat (id, ts, *cols) records,
    per-id ascending ts — one lexsort replaces the per-row Python loop."""
    ids = np.asarray(ids, np.int64)
    ts = np.asarray(ts, np.int32)
    order = np.lexsort((ts, ids))
    row_ptr = np.zeros(num_rows + 1, np.int32)
    np.cumsum(np.bincount(ids, minlength=num_rows), out=row_ptr[1:])
    return row_ptr, ts[order], [np.asarray(c, np.int32)[order] for c in cols]


def pack_index(g: TemporalGraph, k: int, b: IncrementalBuilder) -> PECBIndex:
    N = b.num_nodes
    row_ptr, ent_ts, (ent_l, ent_r, ent_p) = _csr_sorted(
        b.ent_node, b.ent_ts, (b.ent_l, b.ent_r, b.ent_p), N)
    vrow_ptr, vent_ts, (vent_node,) = _csr_sorted(
        b.vent_vert, b.vent_ts, (b.vent_node,), g.n)
    i32 = lambda a: np.ascontiguousarray(a[:N], np.int32)
    return PECBIndex(
        g.n, g.m, g.t_max, k,
        i32(b.n_u), i32(b.n_v), i32(b.n_ct), i32(b.n_edge),
        i32(b.n_live_from), i32(b.n_live_to),
        row_ptr, ent_ts, ent_l, ent_r, ent_p,
        vrow_ptr, vent_ts, vent_node,
        versions=VersionStore.from_table(g, k, b.tab),
    )


def build_pecb_index(g: TemporalGraph, k: int,
                     tab: CoreTimeTable | None = None, *,
                     resume_from: PECBIndex | None = None) -> PECBIndex:
    """End-to-end PECB construction (Alg 3): core times -> incremental
    forest maintenance -> packed index.

    ``resume_from`` is the streaming plane's epoch-resume path: pass the
    previous epoch's index (built for a graph that ``g`` suffix-extends via
    ``TemporalGraph.extend``) together with the extended table ``tab``
    (``extend_core_times``), and the index is *grown* from the previous
    epoch's packed arrays instead of replaying every version
    (``streaming.extend_pecb_index``). The result is bit-identical to a
    cold ``build_pecb_index(g, k)`` (test-asserted)."""
    if resume_from is not None:
        if tab is None:
            raise ValueError(
                "resume_from needs the extended table: pass "
                "tab=extend_core_times(g, k, prev_tab)")
        from .streaming import extend_pecb_index
        return extend_pecb_index(g, k, tab, resume_from)
    tab = tab if tab is not None else edge_core_times(g, k)
    b = IncrementalBuilder(g, tab).run()
    return pack_index(g, k, b)
