"""PECB-Index (paper §4.1 Table 2, §4.2 Algorithm 1).

The incremental builder's per-node entry lists are packed into flat CSR
arrays so that (a) host queries are cache-friendly, (b) the same arrays ship
unchanged to the device for the batched query engine (``batch_query.py``),
and (c) index size accounting is exact (``nbytes``).

Entry resolution for a node at start time ``ts`` is the paper's binary
search: the entry with the smallest recorded start time >= ts (entries are
recorded while ts descends, only on change). Nodes/vertices whose earliest
recorded entry is below ``ts`` are not in the forest at ``ts``.

Query surface: the typed v2 API (``answer(TCCSQuery) -> TCCSResult``, via
:class:`repro.core.query_api.ComponentBackend`) is primary; ``query(u, ts,
te)`` remains as a thin deprecation shim over the same component routine.
The attached :class:`VersionStore` (the core-time table carried through
construction) powers the EDGES/SUBGRAPH modes; it is deliberately excluded
from ``nbytes()`` so the paper's index-size comparison stays undistorted.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .core_time import (CoreTimeTable, StratifiedCoreTable, default_ks,
                        edge_core_times, stratified_core_times)
from .ecb_forest import (NONE, FastIncrementalBuilder, ForestInvariantError,
                        IncrementalBuilder)
from .query_api import (ComponentBackend, InvalidQueryError, Provenance,
                        TCCSQuery, TCCSResult, VersionStore, empty_result)
from .temporal_graph import TemporalGraph


@dataclasses.dataclass
class PECBIndex(ComponentBackend):
    n: int
    m: int
    t_max: int
    k: int
    # node (= edge version) table
    node_u: np.ndarray        # int32[N]
    node_v: np.ndarray        # int32[N]
    node_ct: np.ndarray       # int32[N]
    node_edge: np.ndarray     # int32[N]
    node_live_from: np.ndarray  # int32[N]  (first ts with node in forest)
    node_live_to: np.ndarray    # int32[N]  (last ts with node in forest)
    # node entries, CSR, per-node ascending ts
    row_ptr: np.ndarray       # int32[N+1]
    ent_ts: np.ndarray        # int32[E]
    ent_left: np.ndarray      # int32[E]
    ent_right: np.ndarray     # int32[E]
    ent_parent: np.ndarray    # int32[E]
    # per-vertex entry points, CSR, per-vertex ascending ts
    vrow_ptr: np.ndarray      # int32[n+1]
    vent_ts: np.ndarray       # int32[VE]
    vent_node: np.ndarray     # int32[VE]
    # v2 query surface: per-version membership metadata (EDGES/SUBGRAPH
    # modes); not index payload, excluded from nbytes()
    versions: VersionStore | None = None

    backend_name = "pecb"

    @property
    def num_nodes(self) -> int:
        return int(self.node_u.shape[0])

    def nbytes(self) -> int:
        return sum(
            a.nbytes
            for a in (
                self.node_u, self.node_v, self.node_ct, self.node_edge,
                self.node_live_from, self.node_live_to,
                self.row_ptr, self.ent_ts, self.ent_left, self.ent_right,
                self.ent_parent, self.vrow_ptr, self.vent_ts, self.vent_node,
            )
        )

    # -- entry resolution (the paper's per-node binary search) ----------
    def resolve(self, node: int, ts: int):
        lo, hi = self.row_ptr[node], self.row_ptr[node + 1]
        i = lo + np.searchsorted(self.ent_ts[lo:hi], ts, side="left")
        if i == hi:
            return None  # version not in the forest at this start time
        return int(self.ent_left[i]), int(self.ent_right[i]), int(self.ent_parent[i])

    def entry_node(self, vert: int, ts: int) -> int:
        lo, hi = self.vrow_ptr[vert], self.vrow_ptr[vert + 1]
        i = lo + np.searchsorted(self.vent_ts[lo:hi], ts, side="left")
        if i == hi:
            return NONE
        return int(self.vent_node[i])

    # -- Algorithm 1 -----------------------------------------------------
    def query(self, u: int, ts: int, te: int) -> set[int]:
        """All vertices of the temporal k-core component of u in [ts, te].

        .. deprecated:: kept as a thin shim over the v2 surface; prefer
           ``answer(TCCSQuery(u, ts, te, k))`` which validates, carries
           result modes and records provenance. Emits
           :class:`DeprecationWarning`.
        """
        warnings.warn(
            "PECBIndex.query(u, ts, te) is deprecated; use "
            "answer(TCCSQuery(u, ts, te, k))",
            DeprecationWarning, stacklevel=2)
        return self._component_vertices(u, ts, te)

    def _component_vertices(self, u: int, ts: int, te: int) -> set[int]:
        e0 = self.entry_node(u, ts)
        if e0 == NONE or self.node_ct[e0] > te:
            return set()
        result: set[int] = set()
        seen: set[int] = set()
        stack = [e0]
        while stack:
            e = stack.pop()
            if e in seen:
                continue
            seen.add(e)
            result.add(int(self.node_u[e]))
            result.add(int(self.node_v[e]))
            links = self.resolve(e, ts)
            if links is None:
                # A reachable node must be in the ts-forest; a bare assert
                # here would vanish under `python -O` and silently return a
                # truncated component.
                raise ForestInvariantError(
                    f"query ({u}, {ts}, {te}) reached node {e} outside the "
                    "ts-forest: corrupt index")
            for nb in links:
                if nb != NONE and nb not in seen and self.node_ct[nb] <= te:
                    stack.append(nb)
        return result


def _csr_sorted(ids, ts, cols, num_rows):
    """(row_ptr, sorted column arrays) for flat (id, ts, *cols) records,
    per-id ascending ts — one lexsort replaces the per-row Python loop."""
    ids = np.asarray(ids, np.int64)
    ts = np.asarray(ts, np.int32)
    order = np.lexsort((ts, ids))
    row_ptr = np.zeros(num_rows + 1, np.int32)
    np.cumsum(np.bincount(ids, minlength=num_rows), out=row_ptr[1:])
    return row_ptr, ts[order], [np.asarray(c, np.int32)[order] for c in cols]


def pack_index(g: TemporalGraph, k: int, b: IncrementalBuilder) -> PECBIndex:
    N = b.num_nodes
    row_ptr, ent_ts, (ent_l, ent_r, ent_p) = _csr_sorted(
        b.ent_node, b.ent_ts, (b.ent_l, b.ent_r, b.ent_p), N)
    vrow_ptr, vent_ts, (vent_node,) = _csr_sorted(
        b.vent_vert, b.vent_ts, (b.vent_node,), g.n)
    i32 = lambda a: np.ascontiguousarray(a[:N], np.int32)
    return PECBIndex(
        g.n, g.m, g.t_max, k,
        i32(b.n_u), i32(b.n_v), i32(b.n_ct), i32(b.n_edge),
        i32(b.n_live_from), i32(b.n_live_to),
        row_ptr, ent_ts, ent_l, ent_r, ent_p,
        vrow_ptr, vent_ts, vent_node,
        versions=VersionStore.from_table(g, k, b.tab),
    )


def build_pecb_index(g: TemporalGraph, k: int,
                     tab: CoreTimeTable | None = None, *,
                     resume_from: PECBIndex | None = None) -> PECBIndex:
    """End-to-end PECB construction (Alg 3): core times -> incremental
    forest maintenance -> packed index.

    ``resume_from`` is the streaming plane's epoch-resume path: pass the
    previous epoch's index (built for a graph that ``g`` suffix-extends via
    ``TemporalGraph.extend``) together with the extended table ``tab``
    (``extend_core_times``), and the index is *grown* from the previous
    epoch's packed arrays instead of replaying every version
    (``streaming.extend_pecb_index``). The result is bit-identical to a
    cold ``build_pecb_index(g, k)`` (test-asserted)."""
    if resume_from is not None:
        if tab is None:
            raise ValueError(
                "resume_from needs the extended table: pass "
                "tab=extend_core_times(g, k, prev_tab)")
        from .streaming import extend_pecb_index
        return extend_pecb_index(g, k, tab, resume_from)
    tab = tab if tab is not None else edge_core_times(g, k)
    b = IncrementalBuilder(g, tab).run()
    return pack_index(g, k, b)


# ----------------------------------------------------------------------
# K-stratified index plane: one packed structure serves every k
# (DESIGN.md §14)
# ----------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class StratifiedPECB:
    """All k strata of one workload in a single packed structure.

    Layout: the per-k PECB arrays are concatenated stratum-by-stratum,
    node/entry ids staying *local* to their stratum, with int64 pointer
    tables (``knode_ptr``/``kent_ptr``/``kvent_ptr`` and
    ``strata.kptr``) delimiting the blocks. ``slice_k(k)`` therefore
    returns a :class:`PECBIndex` of pure zero-copy views that is
    bit-identical to a standalone per-k build (test-asserted) — every
    existing host query routine, the device packer and the store
    serializer run unchanged on a slice.

    Version membership (EDGES/SUBGRAPH modes, streaming resume) rides on
    the :class:`StratifiedCoreTable` the construction already produced:
    its record blocks are exactly the per-k :class:`VersionStore`
    payloads, so the only extra per-version state is the endpoint
    columns ``ver_src/ver_dst/ver_t``.

    Query dispatch: ``answer`` routes ``k in supported_ks`` to the
    stratum slice, answers ``k > k_max_graph`` exactly empty (every
    window's k-core is a subgraph of the full-window k-core, which is
    empty beyond the graph's degeneracy), and rejects an in-range but
    unsupported k with :class:`InvalidQueryError` — silence would be a
    wrong answer, not a trivial one.
    """

    n: int
    m: int
    t_max: int
    k_max_graph: int
    ks: tuple
    # per-k node blocks (ids local to each block)
    knode_ptr: np.ndarray       # int64[|K|+1]
    node_u: np.ndarray          # int32[Ntot]
    node_v: np.ndarray
    node_ct: np.ndarray
    node_edge: np.ndarray
    node_live_from: np.ndarray
    node_live_to: np.ndarray
    # node entries: per-k CSR; block for stratum ki spans
    # row_ptr[knode_ptr[ki]+ki : knode_ptr[ki+1]+ki+1] (one extra slot each)
    row_ptr: np.ndarray         # int32[Ntot+|K|]
    kent_ptr: np.ndarray        # int64[|K|+1]
    ent_ts: np.ndarray          # int32[Etot]
    ent_left: np.ndarray
    ent_right: np.ndarray
    ent_parent: np.ndarray
    # vertex entry points: per-k CSR, one (n+1)-slot row_ptr block per k
    vrow_ptr: np.ndarray        # int32[|K|*(n+1)]
    kvent_ptr: np.ndarray       # int64[|K|+1]
    vent_ts: np.ndarray         # int32[VEtot]
    vent_node: np.ndarray
    # version membership: stratified core-time records + endpoint columns
    strata: StratifiedCoreTable | None = None
    ver_src: np.ndarray | None = None
    ver_dst: np.ndarray | None = None
    ver_t: np.ndarray | None = None

    backend_name = "pecb-stratified"

    def __post_init__(self):
        self.ks = tuple(int(k) for k in self.ks)
        self._kset = frozenset(self.ks)
        self._slices: dict[int, PECBIndex] = {}
        self._versions_all: VersionStore | None = None

    @property
    def supported_ks(self) -> tuple:
        return self.ks

    @property
    def versions(self) -> VersionStore | None:
        """One :class:`VersionStore` over ALL strata (``k=0`` marks the
        mixed view — no single k describes it). The device plane's
        version-membership masks index this global space (with the
        ``ver_k`` filter selecting each query's stratum), and
        ``select``/``member_edges`` never consult ``k``, so the serving
        planner can assemble EDGES/SUBGRAPH payloads for mixed-k batches
        through the same store interface as a per-k index."""
        if self.strata is None:
            return None
        if self._versions_all is None:
            self._versions_all = VersionStore(
                n=self.n, t_max=self.t_max, k=0,
                edge_id=self.strata.edge_id,
                ts_from=self.strata.ts_from,
                ts_to=self.strata.ts_to,
                ct=self.strata.ct,
                src=self.ver_src, dst=self.ver_dst, t=self.ver_t)
        return self._versions_all

    @property
    def num_nodes(self) -> int:
        return int(self.node_u.shape[0])

    def nbytes(self) -> int:
        """Index payload: packed arrays + stratum pointer tables. The
        version store (``strata``/``ver_*``) is excluded, mirroring
        :meth:`PECBIndex.nbytes`."""
        return sum(
            a.nbytes
            for a in (
                self.knode_ptr, self.node_u, self.node_v, self.node_ct,
                self.node_edge, self.node_live_from, self.node_live_to,
                self.row_ptr, self.kent_ptr, self.ent_ts, self.ent_left,
                self.ent_right, self.ent_parent, self.vrow_ptr,
                self.kvent_ptr, self.vent_ts, self.vent_node,
            )
        )

    def k_index(self, k: int) -> int:
        try:
            return self.ks.index(int(k))
        except ValueError:
            raise KeyError(f"k={k} not in supported_ks={self.ks}") from None

    def slice_k(self, k: int) -> PECBIndex:
        """The per-k :class:`PECBIndex` view of stratum ``k`` (cached;
        zero-copy; bit-identical to a standalone build)."""
        k = int(k)
        hit = self._slices.get(k)
        if hit is not None:
            return hit
        ki = self.k_index(k)
        s, e = int(self.knode_ptr[ki]), int(self.knode_ptr[ki + 1])
        es, ee = int(self.kent_ptr[ki]), int(self.kent_ptr[ki + 1])
        vs, ve = int(self.kvent_ptr[ki]), int(self.kvent_ptr[ki + 1])
        rs = s + ki
        vr = ki * (self.n + 1)
        versions = None
        if self.strata is not None:
            ss, se = int(self.strata.kptr[ki]), int(self.strata.kptr[ki + 1])
            versions = VersionStore(
                n=self.n, t_max=self.t_max, k=k,
                edge_id=self.strata.edge_id[ss:se],
                ts_from=self.strata.ts_from[ss:se],
                ts_to=self.strata.ts_to[ss:se],
                ct=self.strata.ct[ss:se],
                src=self.ver_src[ss:se], dst=self.ver_dst[ss:se],
                t=self.ver_t[ss:se])
        idx = PECBIndex(
            self.n, self.m, self.t_max, k,
            self.node_u[s:e], self.node_v[s:e], self.node_ct[s:e],
            self.node_edge[s:e], self.node_live_from[s:e],
            self.node_live_to[s:e],
            self.row_ptr[rs:rs + (e - s) + 1],
            self.ent_ts[es:ee], self.ent_left[es:ee],
            self.ent_right[es:ee], self.ent_parent[es:ee],
            self.vrow_ptr[vr:vr + self.n + 1],
            self.vent_ts[vs:ve], self.vent_node[vs:ve],
            versions=versions)
        self._slices[k] = idx
        return idx

    def answer(self, q: TCCSQuery) -> TCCSResult:
        q.validate(n=self.n)
        if q.k in self._kset:
            return self.slice_k(q.k).answer(q)
        if q.k > self.k_max_graph:
            cq = q.canonical(self.t_max)
            prov = Provenance(route="trivial", backend=self.backend_name)
            return empty_result(cq, self.n, prov)
        raise InvalidQueryError(
            f"k={q.k} is not served by this index "
            f"(supported_ks={self.ks}, k_max={self.k_max_graph})")

    def answer_many(self, specs) -> list:
        return [self.answer(q) for q in specs]

    @classmethod
    def from_parts(cls, strata: StratifiedCoreTable,
                   indices: list, k_max_graph: int,
                   ver_src: np.ndarray, ver_dst: np.ndarray,
                   ver_t: np.ndarray) -> "StratifiedPECB":
        ks = strata.ks
        if len(indices) != len(ks):
            raise ValueError("one PECBIndex per stratum required")
        z32 = np.zeros(0, np.int32)

        def ptr(sizes):
            p = np.zeros(len(sizes) + 1, np.int64)
            np.cumsum(np.asarray(sizes, np.int64), out=p[1:])
            return p

        def cat(field):
            arrs = [getattr(ix, field) for ix in indices]
            return np.concatenate(arrs) if arrs else z32.copy()

        return cls(
            n=strata.n, m=strata.m, t_max=strata.t_max,
            k_max_graph=int(k_max_graph), ks=ks,
            knode_ptr=ptr([ix.num_nodes for ix in indices]),
            node_u=cat("node_u"), node_v=cat("node_v"),
            node_ct=cat("node_ct"), node_edge=cat("node_edge"),
            node_live_from=cat("node_live_from"),
            node_live_to=cat("node_live_to"),
            row_ptr=cat("row_ptr"),
            kent_ptr=ptr([ix.ent_ts.shape[0] for ix in indices]),
            ent_ts=cat("ent_ts"), ent_left=cat("ent_left"),
            ent_right=cat("ent_right"), ent_parent=cat("ent_parent"),
            vrow_ptr=cat("vrow_ptr"),
            kvent_ptr=ptr([ix.vent_ts.shape[0] for ix in indices]),
            vent_ts=cat("vent_ts"), vent_node=cat("vent_node"),
            strata=strata, ver_src=ver_src, ver_dst=ver_dst, ver_t=ver_t)


def _assemble_stratified(g: TemporalGraph, stab: StratifiedCoreTable,
                         indices: list, k_max_graph: int) -> StratifiedPECB:
    """Pack per-stratum indices + the stratified table into one
    :class:`StratifiedPECB` (shared by cold build and streaming)."""
    eid = stab.edge_id
    return StratifiedPECB.from_parts(
        stab, indices, k_max_graph,
        ver_src=g.src[eid].astype(np.int32),
        ver_dst=g.dst[eid].astype(np.int32),
        ver_t=g.t[eid].astype(np.int32))


def _forest_builder(g: TemporalGraph, tab: CoreTimeTable):
    """Fastest available forest engine: native C when compilable (the
    stratified plane's |K|-fold build makes this the dominant cost),
    else the list-based Python fast path. Both pack bit-identically to
    the base builder (test-asserted)."""
    from . import ecb_native
    if ecb_native.available():
        return ecb_native.NativeForestBuilder(g, tab).run()
    return FastIncrementalBuilder(g, tab).run()


def build_stratified_index(g: TemporalGraph, ks=None, *,
                           strata: StratifiedCoreTable | None = None,
                           engine: str = "auto") -> StratifiedPECB:
    """One build serving every k: fused stratified core-time sweep, then
    one forest per stratum through the fastest available engine, packed
    into a single :class:`StratifiedPECB`.

    ``ks=None`` covers the graph's full coreness range
    (:func:`default_ks`); pass ``strata`` to reuse a table the streaming
    plane already maintains.
    """
    from .kcore import k_max as _graph_k_max
    stab = strata if strata is not None else stratified_core_times(
        g, ks, engine=engine)
    indices = []
    for k in stab.ks:
        b = _forest_builder(g, stab.table_for(int(k)))
        indices.append(pack_index(g, int(k), b))
    return _assemble_stratified(g, stab, indices, _graph_k_max(g))
