"""PECB-Index (paper §4.1 Table 2, §4.2 Algorithm 1).

The incremental builder's per-node entry lists are packed into flat CSR
arrays so that (a) host queries are cache-friendly, (b) the same arrays ship
unchanged to the device for the batched query engine (``batch_query.py``),
and (c) index size accounting is exact (``nbytes``).

Entry resolution for a node at start time ``ts`` is the paper's binary
search: the entry with the smallest recorded start time >= ts (entries are
recorded while ts descends, only on change). Nodes/vertices whose earliest
recorded entry is below ``ts`` are not in the forest at ``ts``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .core_time import CoreTimeTable, edge_core_times
from .ecb_forest import NONE, IncrementalBuilder
from .temporal_graph import TemporalGraph


@dataclasses.dataclass
class PECBIndex:
    n: int
    m: int
    t_max: int
    k: int
    # node (= edge version) table
    node_u: np.ndarray        # int32[N]
    node_v: np.ndarray        # int32[N]
    node_ct: np.ndarray       # int32[N]
    node_edge: np.ndarray     # int32[N]
    node_live_from: np.ndarray  # int32[N]  (first ts with node in forest)
    node_live_to: np.ndarray    # int32[N]  (last ts with node in forest)
    # node entries, CSR, per-node ascending ts
    row_ptr: np.ndarray       # int32[N+1]
    ent_ts: np.ndarray        # int32[E]
    ent_left: np.ndarray      # int32[E]
    ent_right: np.ndarray     # int32[E]
    ent_parent: np.ndarray    # int32[E]
    # per-vertex entry points, CSR, per-vertex ascending ts
    vrow_ptr: np.ndarray      # int32[n+1]
    vent_ts: np.ndarray       # int32[VE]
    vent_node: np.ndarray     # int32[VE]

    @property
    def num_nodes(self) -> int:
        return int(self.node_u.shape[0])

    def nbytes(self) -> int:
        return sum(
            a.nbytes
            for a in (
                self.node_u, self.node_v, self.node_ct, self.node_edge,
                self.node_live_from, self.node_live_to,
                self.row_ptr, self.ent_ts, self.ent_left, self.ent_right,
                self.ent_parent, self.vrow_ptr, self.vent_ts, self.vent_node,
            )
        )

    # -- entry resolution (the paper's per-node binary search) ----------
    def resolve(self, node: int, ts: int):
        lo, hi = self.row_ptr[node], self.row_ptr[node + 1]
        i = lo + np.searchsorted(self.ent_ts[lo:hi], ts, side="left")
        if i == hi:
            return None  # version not in the forest at this start time
        return int(self.ent_left[i]), int(self.ent_right[i]), int(self.ent_parent[i])

    def entry_node(self, vert: int, ts: int) -> int:
        lo, hi = self.vrow_ptr[vert], self.vrow_ptr[vert + 1]
        i = lo + np.searchsorted(self.vent_ts[lo:hi], ts, side="left")
        if i == hi:
            return NONE
        return int(self.vent_node[i])

    # -- Algorithm 1 -----------------------------------------------------
    def query(self, u: int, ts: int, te: int) -> set[int]:
        """All vertices of the temporal k-core component of u in [ts, te]."""
        e0 = self.entry_node(u, ts)
        if e0 == NONE or self.node_ct[e0] > te:
            return set()
        result: set[int] = set()
        seen: set[int] = set()
        stack = [e0]
        while stack:
            e = stack.pop()
            if e in seen:
                continue
            seen.add(e)
            result.add(int(self.node_u[e]))
            result.add(int(self.node_v[e]))
            links = self.resolve(e, ts)
            assert links is not None, "reached a node outside the ts-forest"
            for nb in links:
                if nb != NONE and nb not in seen and self.node_ct[nb] <= te:
                    stack.append(nb)
        return result


def pack_index(g: TemporalGraph, k: int, b: IncrementalBuilder) -> PECBIndex:
    N = len(b.n_edge)
    node_u = np.asarray(b.n_u, np.int32) if N else np.zeros(0, np.int32)
    node_v = np.asarray(b.n_v, np.int32) if N else np.zeros(0, np.int32)
    node_ct = np.asarray(b.n_ct, np.int32) if N else np.zeros(0, np.int32)
    node_edge = np.asarray(b.n_edge, np.int32) if N else np.zeros(0, np.int32)
    live_from = np.asarray(b.n_live_from, np.int32) if N else np.zeros(0, np.int32)
    live_to = np.asarray(b.n_live_to, np.int32) if N else np.zeros(0, np.int32)

    row_ptr = np.zeros(N + 1, np.int32)
    ts_l, l_l, r_l, p_l = [], [], [], []
    for x in range(N):
        ent = b.entries[x][::-1]  # ascending ts
        row_ptr[x + 1] = row_ptr[x] + len(ent)
        for (ts, l, r, p) in ent:
            ts_l.append(ts); l_l.append(l); r_l.append(r); p_l.append(p)
    vrow_ptr = np.zeros(g.n + 1, np.int32)
    vts_l, vn_l = [], []
    for vert in range(g.n):
        ent = b.ventries[vert][::-1]
        vrow_ptr[vert + 1] = vrow_ptr[vert] + len(ent)
        for (ts, node) in ent:
            vts_l.append(ts); vn_l.append(node)

    return PECBIndex(
        g.n, g.m, g.t_max, k,
        node_u, node_v, node_ct, node_edge, live_from, live_to,
        row_ptr,
        np.asarray(ts_l, np.int32), np.asarray(l_l, np.int32),
        np.asarray(r_l, np.int32), np.asarray(p_l, np.int32),
        vrow_ptr,
        np.asarray(vts_l, np.int32), np.asarray(vn_l, np.int32),
    )


def build_pecb_index(g: TemporalGraph, k: int,
                     tab: CoreTimeTable | None = None) -> PECBIndex:
    """End-to-end PECB construction (Alg 3): core times -> incremental
    forest maintenance -> packed index."""
    tab = tab if tab is not None else edge_core_times(g, k)
    b = IncrementalBuilder(g, tab).run()
    return pack_index(g, k, b)
