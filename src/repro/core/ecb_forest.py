"""ECB-Forest (paper §4.1, Def 4.9) and its incremental maintenance (§5).

Forest nodes are *versions*: (graph edge, core time) pairs — the paper treats
an edge whose core time changes as a new parallel edge (Table 2: e10/e11).
Rank is the paper's total order: ``(CT, edge_id)`` ascending (edge ids are
assigned in ``(t, u, v)`` order by :class:`TemporalGraph`, matching the
paper's tie-break and its Table 2 numbering).

Two constructions are provided:

* :func:`build_forest_at` — from-scratch per start time, directly from
  Def 4.9: Kruskal over ranks, then a union-find sweep in ascending rank
  where each component tracks its maximum-rank node; a new node's left/right
  children are the component maxima of its endpoints. Used as the reference
  (uniqueness of the ECB forest follows from the total order) and by tests.

* :class:`IncrementalBuilder` — the paper's Algorithm 2/3. For each new node
  we locate ``l, r, eu, ev`` (findInsertion: incidence lookup + parent-chain
  climb, O(h)) and then run the WE-operator cascade. We implement the cascade
  as an explicit *sorted zipper merge* of the two ancestor chains: each loop
  iteration re-hangs the lowest-ranked pending attachment (one WE
  application); when the chains meet, the meeting node is the LCA of
  Lemma 5.7 — the expired edge — and is deleted, its parent adopting the
  merged chain. Hand-traced against the paper's Table 2 / Figure 3 example
  (ts = 4, 3, 2): reproduces every entry including the e11 expiry, the e10
  skip, and the e12 LCA deletion; also tested against
  :func:`build_forest_at` on random graphs for every start time.
"""

from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from .core_time import CoreTimeTable

NONE = -1


# ----------------------------------------------------------------------
# From-scratch reference construction (Def 4.9)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class ForestSnapshot:
    """ECB forest for one start time. Arrays indexed by *version id* into the
    version table of the CoreTimeTable ordering used to build it."""

    version_key: dict  # (edge_id, ct) -> local node index
    u: np.ndarray
    v: np.ndarray
    ct: np.ndarray
    edge_id: np.ndarray
    left: np.ndarray
    right: np.ndarray
    parent: np.ndarray
    in_forest: np.ndarray  # bool; False = version active at ts but not in MSF


def active_versions(tab: CoreTimeTable, ts: int):
    """(edge_id, ct) of versions valid at start time ts, rank-sorted."""
    sel = (tab.ts_from <= ts) & (ts <= tab.ts_to)
    e, c = tab.edge_id[sel], tab.ct[sel]
    order = np.lexsort((e, c))
    return e[order], c[order]


def build_forest_at(g, tab: CoreTimeTable, ts: int) -> ForestSnapshot:
    e_ids, cts = active_versions(tab, ts)
    nn = e_ids.shape[0]
    u = g.src[e_ids].astype(np.int64)
    v = g.dst[e_ids].astype(np.int64)
    left = np.full(nn, NONE, np.int64)
    right = np.full(nn, NONE, np.int64)
    parent = np.full(nn, NONE, np.int64)
    in_forest = np.zeros(nn, bool)

    # union-find over graph vertices; each root remembers the max-rank node
    uf = {}
    comp_max = {}

    def find(x):
        root = x
        while uf.get(root, root) != root:
            root = uf[root]
        while uf.get(x, x) != x:
            uf[x], x = root, uf[x]
        return root

    for i in range(nn):
        a, b = int(u[i]), int(v[i])
        ra, rb = find(a), find(b)
        if ra == rb:
            continue  # not in MSF (cycle)
        in_forest[i] = True
        la = comp_max.get(ra, NONE)
        lb = comp_max.get(rb, NONE)
        left[i], right[i] = la, lb
        if la != NONE:
            parent[la] = i
        if lb != NONE:
            parent[lb] = i
        uf[ra] = rb
        comp_max[rb] = i
        comp_max.pop(ra, None)

    key = {(int(e_ids[i]), int(cts[i])): i for i in range(nn)}
    return ForestSnapshot(key, u, v, cts.astype(np.int64), e_ids.astype(np.int64),
                          left, right, parent, in_forest)


# ----------------------------------------------------------------------
# Incremental builder (Algorithms 2 and 3)
# ----------------------------------------------------------------------

class IncrementalBuilder:
    """Maintains the ECB forest while the start time descends, recording
    delta-compressed PECB entries (paper §4.1) plus per-vertex entry-point
    versions for Algorithm 1 line 3."""

    def __init__(self, g, tab: CoreTimeTable):
        self.g = g
        self.tab = tab
        # node store (parallel lists, grown by insert)
        self.n_edge: list[int] = []
        self.n_ct: list[int] = []
        self.n_u: list[int] = []
        self.n_v: list[int] = []
        self.n_child: list[list[int]] = []   # [slot0, slot1] aligned to (u, v)
        self.n_parent: list[int] = []
        self.n_in: list[bool] = []
        # per-vertex sorted incidence: list of (ct, edge_id, node_id)
        self.inc: list[list[tuple]] = [[] for _ in range(g.n)]
        # recorded entries: per node list of (ts, l, r, p) in build (desc-ts) order
        self.entries: list[list[tuple]] = []
        self.ventries: list[list[tuple]] = [[] for _ in range(g.n)]
        # forest-membership lifetime per node: [live_from, live_to] inclusive.
        # live_to = the start time whose processing inserted the node;
        # live_from = (deletion start time + 1), or 1 if never deleted.
        # The device query plane (batch_query.py) needs these to mask the
        # stale links of dead nodes; the host DFS never reaches them.
        self.n_live_to: list[int] = []
        self.n_live_from: list[int] = []
        self._cur_ts: int = 0
        self._dirty_nodes: set[int] = set()
        self._dirty_verts: set[int] = set()

    # -- helpers --------------------------------------------------------
    def rank(self, x: int) -> tuple:
        return (self.n_ct[x], self.n_edge[x])

    def _new_node(self, edge_id: int, ct: int) -> int:
        x = len(self.n_edge)
        self.n_edge.append(edge_id)
        self.n_ct.append(ct)
        self.n_u.append(int(self.g.src[edge_id]))
        self.n_v.append(int(self.g.dst[edge_id]))
        self.n_child.append([NONE, NONE])
        self.n_parent.append(NONE)
        self.n_in.append(False)
        self.entries.append([])
        self.n_live_to.append(self._cur_ts)
        self.n_live_from.append(1)
        return x

    def _slot_of(self, node: int, child: int) -> int:
        c = self.n_child[node]
        if c[0] == child:
            return 0
        assert c[1] == child, (node, child, c)
        return 1

    def _slot_for_vertex(self, node: int, vert: int) -> int:
        return 0 if self.n_u[node] == vert else 1

    def _inc_add(self, vert: int, node: int):
        bisect.insort(self.inc[vert], (self.n_ct[node], self.n_edge[node], node))
        self._dirty_verts.add(vert)

    def _inc_remove(self, vert: int, node: int):
        key = (self.n_ct[node], self.n_edge[node], node)
        i = bisect.bisect_left(self.inc[vert], key)
        assert self.inc[vert][i] == key
        self.inc[vert].pop(i)
        self._dirty_verts.add(vert)

    def _find_side(self, vert: int, rk: tuple):
        """findInsertion for one endpoint: returns (child, attach, via_slot).

        child  = component maximum below ``rk`` on this side (Def 4.9 child),
        attach = its old parent / lowest incident node above ``rk``,
        via_slot = slot index in ``attach`` consumed by the merge.
        """
        lst = self.inc[vert]
        i = bisect.bisect_left(lst, (rk[0], rk[1], -(10 ** 18)))
        child = lst[i - 1][2] if i > 0 else NONE
        attach = lst[i][2] if i < len(lst) else NONE
        if child != NONE:
            # climb to the component maximum below rk (Alg 2 lines 5-9)
            while self.n_parent[child] != NONE and self.rank(self.n_parent[child]) < rk:
                child = self.n_parent[child]
            attach = self.n_parent[child]
            via = self._slot_of(attach, child) if attach != NONE else NONE
        else:
            via = self._slot_for_vertex(attach, vert) if attach != NONE else NONE
            if attach != NONE:
                assert self.n_child[attach][via] == NONE
        return child, attach, via

    # -- core insert (Alg 2 + Alg 3 Merge/WE cascade as a zipper) --------
    def insert(self, edge_id: int, ct: int) -> int | None:
        """Insert the version (edge_id, ct); returns the expired node or None.
        Returns None without side effects when the version joins no MSF."""
        g = self.g
        uu, vv = int(g.src[edge_id]), int(g.dst[edge_id])
        rk = (ct, edge_id)
        l, eu, via_u = self._find_side(uu, rk)
        r, ev, via_v = self._find_side(vv, rk)
        if l != NONE and l == r:
            # u, v already connected below rk: the new edge is the
            # highest-ranked edge of the induced cycle -> not in the MSF.
            return None

        x = self._new_node(edge_id, ct)
        self.n_in[x] = True
        self.n_child[x][0] = l
        self.n_child[x][1] = r
        if l != NONE:
            self.n_parent[l] = x
            self._dirty_nodes.add(l)
        if r != NONE:
            self.n_parent[r] = x
            self._dirty_nodes.add(r)
        self._inc_add(uu, x)
        self._inc_add(vv, x)
        self._dirty_nodes.add(x)

        # zipper merge of the two ancestor chains (WE-operator cascade)
        via = {}
        if eu != NONE:
            via[eu] = via_u
        if ev != NONE:
            via[ev] = via_v
        cur, a, b = x, eu, ev
        expired = None
        while True:
            if a == NONE and b == NONE:
                self.n_parent[cur] = NONE
                break
            if a == NONE or b == NONE:
                t = a if a != NONE else b
                self.n_parent[cur] = t
                self.n_child[t][via[t]] = cur
                self._dirty_nodes.add(t)
                break
            if a == b:
                # Lemma 5.7: the meeting node is the cycle's LCA -> expired
                expired = a
                p = self.n_parent[a]
                self.n_parent[cur] = p
                if p != NONE:
                    self.n_child[p][self._slot_of(p, a)] = cur
                    self._dirty_nodes.add(p)
                self._delete_node(a)
                break
            lo, hi = (a, b) if self.rank(a) < self.rank(b) else (b, a)
            nxt = self.n_parent[lo]
            self.n_parent[cur] = lo
            self.n_child[lo][via[lo]] = cur
            self._dirty_nodes.add(lo)
            if nxt != NONE:
                via[nxt] = self._slot_of(nxt, lo)
            cur, a, b = lo, nxt, hi
        return expired

    def _delete_node(self, x: int):
        self.n_in[x] = False
        self.n_live_from[x] = self._cur_ts + 1
        self._inc_remove(self.n_u[x], x)
        self._inc_remove(self.n_v[x], x)
        self._dirty_nodes.discard(x)

    # -- per-ts entry flush ----------------------------------------------
    def flush(self, ts: int):
        """Record delta entries for everything that changed at this start
        time (paper: an item is stored only if the neighbourhood differs
        from the previous start time)."""
        for x in self._dirty_nodes:
            if not self.n_in[x]:
                continue
            val = (self.n_child[x][0], self.n_child[x][1], self.n_parent[x])
            ent = self.entries[x]
            if not ent or (ent[-1][1], ent[-1][2], ent[-1][3]) != val:
                ent.append((ts, *val))
        for vert in self._dirty_verts:
            lst = self.inc[vert]
            node = lst[0][2] if lst else NONE
            ent = self.ventries[vert]
            if not ent or ent[-1][1] != node:
                ent.append((ts, node))
        self._dirty_nodes.clear()
        self._dirty_verts.clear()

    # -- full build -------------------------------------------------------
    def run(self):
        """Process all version records in descending start time (Alg 3)."""
        tab = self.tab
        order = np.lexsort((tab.edge_id, tab.ct, -tab.ts_to))
        i, R = 0, order.shape[0]
        for ts in range(tab.t_max, 0, -1):
            self._cur_ts = ts
            while i < R and int(tab.ts_to[order[i]]) == ts:
                ridx = order[i]
                self.insert(int(tab.edge_id[ridx]), int(tab.ct[ridx]))
                i += 1
            self.flush(ts)
        assert i == R, (i, R)
        return self
