"""ECB-Forest (paper §4.1, Def 4.9) and its incremental maintenance (§5).

Forest nodes are *versions*: (graph edge, core time) pairs — the paper treats
an edge whose core time changes as a new parallel edge (Table 2: e10/e11).
Rank is the paper's total order: ``(CT, edge_id)`` ascending (edge ids are
assigned in ``(t, u, v)`` order by :class:`TemporalGraph`, matching the
paper's tie-break and its Table 2 numbering). Internally ranks are packed as
``ct * (m + 1) + edge_id`` in int64 so one scalar compare replaces the tuple
compare.

Two constructions are provided:

* :func:`build_forest_at` — from-scratch per start time, directly from
  Def 4.9: Kruskal over ranks, then a union-find sweep in ascending rank
  where each component tracks its maximum-rank node; a new node's left/right
  children are the component maxima of its endpoints. Used as the reference
  (uniqueness of the ECB forest follows from the total order) and by tests.

* :class:`IncrementalBuilder` — the paper's Algorithm 2/3. For each new node
  we locate ``l, r, eu, ev`` (findInsertion: incidence lookup + parent-chain
  climb, O(h)) and then run the WE-operator cascade. We implement the cascade
  as an explicit *sorted zipper merge* of the two ancestor chains: each loop
  iteration re-hangs the lowest-ranked pending attachment (one WE
  application); when the chains meet, the meeting node is the LCA of
  Lemma 5.7 — the expired edge — and is deleted, its parent adopting the
  merged chain. Hand-traced against the paper's Table 2 / Figure 3 example
  (ts = 4, 3, 2): reproduces every entry including the e11 expiry, the e10
  skip, and the e12 LCA deletion; also tested against
  :func:`build_forest_at` on random graphs for every start time.

PR 2 rebuilt the builder's hot structures as numpy-backed stores:

* the node table is a set of preallocated flat arrays (one slot per version
  record — an upper bound on inserts), not per-node Python lists;
* per-vertex incidence is a pair of parallel sorted lists of *packed int
  ranks* + node ids (C bisect/insort; no tuple allocation, and for the tiny
  lists a live forest produces, cheaper than numpy's per-scalar
  searchsorted overhead);
* delta entries go to flat append buffers deduplicated against a packed
  ``last recorded (l, r, p)`` array; ``pack_index`` turns them into the CSR
  arrays with one lexsort instead of a per-node Python loop;
* a bulk *MSF prefilter* (Def 4.9: the forest at any start time is the
  unique rank-MSF of the active versions, the invariant
  ``tests/test_system.py::test_incremental_equals_from_scratch`` asserts)
  rejects the ~95+% of candidate versions that join no MSF before they ever
  reach the Python insert path. ``insert`` keeps its own cycle check, so the
  prefilter is a pure accelerator: a false *accept* costs one wasted insert
  attempt; false rejects cannot occur (the MSF is exact). Small inputs run
  a direct Kruskal (the fixed sparse-matrix cost dominates there); large
  ones use scipy's C MSF, or Kruskal again when scipy is unavailable.

Invariant violations raise :class:`ForestInvariantError` instead of bare
``assert`` (which vanishes under ``python -O`` and would corrupt the index
silently).
"""

from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from .core_time import CoreTimeTable

NONE = -1

try:  # the prefilter's MSF runs in C; optional (see module docstring)
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import minimum_spanning_tree
    _HAVE_SCIPY = True
except ImportError:  # pragma: no cover - scipy is bundled in CI/dev images
    _HAVE_SCIPY = False


class ForestInvariantError(RuntimeError):
    """A structural invariant of the ECB forest was violated (corrupt
    builder state); raised eagerly so a broken index is never served."""


# ----------------------------------------------------------------------
# From-scratch reference construction (Def 4.9)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class ForestSnapshot:
    """ECB forest for one start time. Arrays indexed by *version id* into the
    version table of the CoreTimeTable ordering used to build it."""

    version_key: dict  # (edge_id, ct) -> local node index
    u: np.ndarray
    v: np.ndarray
    ct: np.ndarray
    edge_id: np.ndarray
    left: np.ndarray
    right: np.ndarray
    parent: np.ndarray
    in_forest: np.ndarray  # bool; False = version active at ts but not in MSF


def active_versions(tab: CoreTimeTable, ts: int):
    """(edge_id, ct) of versions valid at start time ts, rank-sorted."""
    sel = (tab.ts_from <= ts) & (ts <= tab.ts_to)
    e, c = tab.edge_id[sel], tab.ct[sel]
    order = np.lexsort((e, c))
    return e[order], c[order]


def build_forest_at(g, tab: CoreTimeTable, ts: int) -> ForestSnapshot:
    e_ids, cts = active_versions(tab, ts)
    nn = e_ids.shape[0]
    u = g.src[e_ids].astype(np.int64)
    v = g.dst[e_ids].astype(np.int64)
    left = np.full(nn, NONE, np.int64)
    right = np.full(nn, NONE, np.int64)
    parent = np.full(nn, NONE, np.int64)
    in_forest = np.zeros(nn, bool)

    # union-find over graph vertices; each root remembers the max-rank node
    uf = {}
    comp_max = {}

    def find(x):
        root = x
        while uf.get(root, root) != root:
            root = uf[root]
        while uf.get(x, x) != x:
            uf[x], x = root, uf[x]
        return root

    for i in range(nn):
        a, b = int(u[i]), int(v[i])
        ra, rb = find(a), find(b)
        if ra == rb:
            continue  # not in MSF (cycle)
        in_forest[i] = True
        la = comp_max.get(ra, NONE)
        lb = comp_max.get(rb, NONE)
        left[i], right[i] = la, lb
        if la != NONE:
            parent[la] = i
        if lb != NONE:
            parent[lb] = i
        uf[ra] = rb
        comp_max[rb] = i
        comp_max.pop(ra, None)

    key = {(int(e_ids[i]), int(cts[i])): i for i in range(nn)}
    return ForestSnapshot(key, u, v, cts.astype(np.int64), e_ids.astype(np.int64),
                          left, right, parent, in_forest)


# ----------------------------------------------------------------------
# Incremental builder (Algorithms 2 and 3)
# ----------------------------------------------------------------------

class IncrementalBuilder:
    """Maintains the ECB forest while the start time descends, recording
    delta-compressed PECB entries (paper §4.1) plus per-vertex entry-point
    versions for Algorithm 1 line 3. See the module docstring for the
    numpy-backed store layout and the MSF candidate prefilter."""

    def __init__(self, g, tab: CoreTimeTable, *, prefilter: bool = True):
        self.g = g
        self.tab = tab
        self.prefilter = prefilter
        R = tab.num_versions
        self._cap = R
        self._stride = np.int64(g.m + 1)       # rank = ct * stride + edge
        # scipy MSF carries weights as float64: only exact while every
        # packed rank fits the 53-bit mantissa (else Kruskal, always exact)
        self._scipy_exact = (tab.t_max + 1) * (g.m + 1) < 2 ** 53
        # node store: preallocated flat arrays (<= one insert per record)
        self.n_edge = np.zeros(R, np.int32)
        self.n_ct = np.zeros(R, np.int32)
        self.n_u = np.zeros(R, np.int32)
        self.n_v = np.zeros(R, np.int32)
        self.n_child = np.full((R, 2), NONE, np.int32)  # aligned to (u, v)
        self.n_parent = np.full(R, NONE, np.int32)
        self.n_in = np.zeros(R, bool)
        self.n_rank = np.zeros(R, np.int64)
        self.num_nodes = 0
        # forest-membership lifetime per node: [live_from, live_to] inclusive.
        # live_to = the start time whose processing inserted the node;
        # live_from = (deletion start time + 1), or 1 if never deleted.
        # The device query plane (batch_query.py) needs these to mask the
        # stale links of dead nodes; the host DFS never reaches them.
        self.n_live_from = np.ones(R, np.int32)
        self.n_live_to = np.zeros(R, np.int32)
        # per-vertex sorted incidence: parallel lists of packed int ranks +
        # node ids. Plain ints (no tuples: the seed's allocation hotspot)
        # with C bisect/insort — for the tiny per-vertex lists a live forest
        # produces, this beats numpy's per-scalar searchsorted overhead.
        self._inc_key: list[list[int]] = [[] for _ in range(g.n)]
        self._inc_node: list[list[int]] = [[] for _ in range(g.n)]
        # live-node registry (swap-remove) feeding the MSF prefilter
        self._live = np.zeros(R, np.int32)
        self._live_pos = np.full(R, -1, np.int64)
        self._nlive = 0
        # delta-entry buffers; pack_index CSR-ifies them with one lexsort
        self.ent_node: list[int] = []
        self.ent_ts: list[int] = []
        self.ent_l: list[int] = []
        self.ent_r: list[int] = []
        self.ent_p: list[int] = []
        self.vent_vert: list[int] = []
        self.vent_ts: list[int] = []
        self.vent_node: list[int] = []
        # last-recorded (l, r, p) per node / entry node per vertex; -2 is
        # "never recorded" (NONE = -1 is a legal value)
        self._last = np.full((R, 3), -2, np.int32)
        self._last_vent = np.full(g.n, -2, np.int64)
        self._cur_ts: int = 0
        self._dirty_nodes: set[int] = set()
        self._dirty_verts: set[int] = set()

    # -- helpers --------------------------------------------------------
    def rank(self, x: int) -> tuple:
        return (int(self.n_ct[x]), int(self.n_edge[x]))

    def _new_node(self, edge_id: int, ct: int) -> int:
        x = self.num_nodes
        if x >= self._cap:
            raise ForestInvariantError(
                f"more inserts than version records ({self._cap})")
        self.num_nodes = x + 1
        self.n_edge[x] = edge_id
        self.n_ct[x] = ct
        self.n_u[x] = self.g.src[edge_id]
        self.n_v[x] = self.g.dst[edge_id]
        self.n_rank[x] = np.int64(ct) * self._stride + edge_id
        self.n_live_to[x] = self._cur_ts
        return x

    def _live_add(self, x: int):
        self._live[self._nlive] = x
        self._live_pos[x] = self._nlive
        self._nlive += 1

    def _live_remove(self, x: int):
        pos = int(self._live_pos[x])
        if pos < 0:
            raise ForestInvariantError(f"node {x} not live")
        last = self._nlive - 1
        mv = self._live[last]
        self._live[pos] = mv
        self._live_pos[mv] = pos
        self._live_pos[x] = -1
        self._nlive = last

    def _slot_of(self, node: int, child: int) -> int:
        c = self.n_child[node]
        if c[0] == child:
            return 0
        if c[1] != child:
            raise ForestInvariantError(
                f"node {child} is not a child of {node} ({c.tolist()})")
        return 1

    def _slot_for_vertex(self, node: int, vert: int) -> int:
        return 0 if self.n_u[node] == vert else 1

    def _inc_add(self, vert: int, node: int, key: int):
        keys = self._inc_key[vert]
        i = bisect.bisect_left(keys, key)
        keys.insert(i, key)
        self._inc_node[vert].insert(i, node)
        self._dirty_verts.add(vert)

    def _inc_remove(self, vert: int, node: int):
        keys = self._inc_key[vert]
        nodes = self._inc_node[vert]
        i = bisect.bisect_left(keys, int(self.n_rank[node]))
        if i >= len(keys) or nodes[i] != node:
            raise ForestInvariantError(
                f"node {node} missing from vertex {vert} incidence")
        keys.pop(i)
        nodes.pop(i)
        self._dirty_verts.add(vert)

    def _find_side(self, vert: int, rk: int):
        """findInsertion for one endpoint: returns (child, attach, via_slot).

        child  = component maximum below ``rk`` on this side (Def 4.9 child),
        attach = its old parent / lowest incident node above ``rk``,
        via_slot = slot index in ``attach`` consumed by the merge.
        """
        keys, nodes = self._inc_key[vert], self._inc_node[vert]
        cnt = len(keys)
        i = bisect.bisect_left(keys, rk)
        child = nodes[i - 1] if i > 0 else NONE
        attach = nodes[i] if i < cnt else NONE
        if child != NONE:
            # climb to the component maximum below rk (Alg 2 lines 5-9)
            parent, rank = self.n_parent, self.n_rank
            p = int(parent[child])
            while p != NONE and rank[p] < rk:
                child = p
                p = int(parent[child])
            attach = p
            via = self._slot_of(attach, child) if attach != NONE else NONE
        else:
            via = self._slot_for_vertex(attach, vert) if attach != NONE else NONE
            if attach != NONE and self.n_child[attach, via] != NONE:
                raise ForestInvariantError(
                    f"entry slot {via} of node {attach} unexpectedly taken")
        return child, attach, via

    # -- bulk candidate prefilter (Def 4.9 MSF membership) ---------------
    #: below this many (live + candidate) edges a direct Kruskal beats the
    #: fixed per-call cost of building a sparse matrix + scipy MST
    _KRUSKAL_CUTOVER = 128

    def _accept_mask(self, cand_edge: np.ndarray,
                     cand_ct: np.ndarray) -> np.ndarray:
        """bool mask: which candidate versions can join the forest at the
        current start time. Exact: a candidate joins iff it is in the unique
        rank-MSF over (live nodes + candidates)."""
        nc = cand_edge.shape[0]
        if not self.prefilter or nc == 0:
            return np.ones(nc, bool)
        n = self.g.n
        live = self._live[:self._nlive]
        crank = cand_ct.astype(np.int64) * self._stride + cand_edge
        u = np.concatenate([self.n_u[live], self.g.src[cand_edge]]).astype(np.int64)
        v = np.concatenate([self.n_v[live], self.g.dst[cand_edge]]).astype(np.int64)
        wt = np.concatenate([self.n_rank[live], crank])
        if (wt.shape[0] <= self._KRUSKAL_CUTOVER or not _HAVE_SCIPY
                or not self._scipy_exact):
            # Kruskal in rank order; parallel pairs need no dedup (the
            # union-find rejects the higher-ranked duplicate naturally)
            order = np.argsort(wt, kind="stable")
            nl = live.shape[0]
            parent = {}

            def find(x):
                root = x
                while parent.get(root, root) != root:
                    root = parent[root]
                while parent.get(x, x) != x:
                    parent[x], x = root, parent[x]
                return root

            accept = np.zeros(nc, bool)
            for i in order.tolist():
                ra, rb = find(int(u[i])), find(int(v[i]))
                if ra != rb:
                    parent[ra] = rb
                    if i >= nl:
                        accept[i - nl] = True
            return accept
        key = np.minimum(u, v) * n + np.maximum(u, v)
        order = np.lexsort((wt, key))
        key_s, wt_s = key[order], wt[order]
        first = np.ones(key_s.shape[0], bool)
        first[1:] = key_s[1:] != key_s[:-1]   # min-rank edge per vertex pair
        ek, ew = key_s[first], wt_s[first]
        # compact vertex ids + direct CSR build: the per-call cost is fixed
        # overhead (matrix conversion, O(n) Prim init), not the MSF itself,
        # and this runs once per start time
        r, c = ek // n, ek % n
        verts, inv = np.unique(np.concatenate([r, c]), return_inverse=True)
        nv = verts.shape[0]
        ri, ci = inv[:r.shape[0]], inv[r.shape[0]:]
        csr_order = np.argsort(ri, kind="stable")
        indptr = np.zeros(nv + 1, np.int32)
        np.cumsum(np.bincount(ri, minlength=nv), out=indptr[1:])
        mat = csr_matrix(((ew[csr_order] + 1).astype(np.float64),
                          ci[csr_order].astype(np.int32), indptr),
                         shape=(nv, nv))
        kept = (np.asarray(minimum_spanning_tree(mat).data) - 1).astype(np.int64)
        return np.isin(crank, kept)

    # -- core insert (Alg 2 + Alg 3 Merge/WE cascade as a zipper) --------
    def insert(self, edge_id: int, ct: int) -> int | None:
        """Insert the version (edge_id, ct); returns the expired node or None.
        Returns None without side effects when the version joins no MSF."""
        g = self.g
        uu, vv = int(g.src[edge_id]), int(g.dst[edge_id])
        if uu == vv:
            # self-loops are degenerate for k-core (from_edges drops them,
            # but direct construction admits them); inserting one would run
            # the zipper against a single vertex and corrupt the forest
            return None
        rk = int(np.int64(ct) * self._stride + edge_id)
        l, eu, via_u = self._find_side(uu, rk)
        r, ev, via_v = self._find_side(vv, rk)
        if l != NONE and l == r:
            # u, v already connected below rk: the new edge is the
            # highest-ranked edge of the induced cycle -> not in the MSF.
            return None

        x = self._new_node(edge_id, ct)
        self.n_in[x] = True
        self.n_child[x, 0] = l
        self.n_child[x, 1] = r
        if l != NONE:
            self.n_parent[l] = x
            self._dirty_nodes.add(l)
        if r != NONE:
            self.n_parent[r] = x
            self._dirty_nodes.add(r)
        self._inc_add(uu, x, rk)
        self._inc_add(vv, x, rk)
        self._live_add(x)
        self._dirty_nodes.add(x)

        # zipper merge of the two ancestor chains (WE-operator cascade)
        via = {}
        if eu != NONE:
            via[eu] = via_u
        if ev != NONE:
            via[ev] = via_v
        cur, a, b = x, eu, ev
        expired = None
        rank = self.n_rank
        while True:
            if a == NONE and b == NONE:
                self.n_parent[cur] = NONE
                break
            if a == NONE or b == NONE:
                t = a if a != NONE else b
                self.n_parent[cur] = t
                self.n_child[t, via[t]] = cur
                self._dirty_nodes.add(t)
                break
            if a == b:
                # Lemma 5.7: the meeting node is the cycle's LCA -> expired
                expired = a
                p = int(self.n_parent[a])
                self.n_parent[cur] = p
                if p != NONE:
                    self.n_child[p, self._slot_of(p, a)] = cur
                    self._dirty_nodes.add(p)
                self._delete_node(a)
                break
            lo, hi = (a, b) if rank[a] < rank[b] else (b, a)
            nxt = int(self.n_parent[lo])
            self.n_parent[cur] = lo
            self.n_child[lo, via[lo]] = cur
            self._dirty_nodes.add(lo)
            if nxt != NONE:
                via[nxt] = self._slot_of(nxt, lo)
            cur, a, b = lo, nxt, hi
        return expired

    def _delete_node(self, x: int):
        self.n_in[x] = False
        self.n_live_from[x] = self._cur_ts + 1
        self._inc_remove(int(self.n_u[x]), x)
        self._inc_remove(int(self.n_v[x]), x)
        self._live_remove(x)
        self._dirty_nodes.discard(x)

    # -- per-ts entry flush ----------------------------------------------
    def flush(self, ts: int):
        """Record delta entries for everything that changed at this start
        time (paper: an item is stored only if the neighbourhood differs
        from the previous start time)."""
        last = self._last
        for x in self._dirty_nodes:
            if not self.n_in[x]:
                continue
            l = int(self.n_child[x, 0])
            r = int(self.n_child[x, 1])
            p = int(self.n_parent[x])
            if last[x, 0] != l or last[x, 1] != r or last[x, 2] != p:
                last[x, 0] = l
                last[x, 1] = r
                last[x, 2] = p
                self.ent_node.append(x)
                self.ent_ts.append(ts)
                self.ent_l.append(l)
                self.ent_r.append(r)
                self.ent_p.append(p)
        for vert in self._dirty_verts:
            lst = self._inc_node[vert]
            node = lst[0] if lst else NONE
            if self._last_vent[vert] != node:
                self._last_vent[vert] = node
                self.vent_vert.append(vert)
                self.vent_ts.append(ts)
                self.vent_node.append(node)
        self._dirty_nodes.clear()
        self._dirty_verts.clear()

    # -- full build -------------------------------------------------------
    def run(self):
        """Process all version records in descending start time (Alg 3):
        per ts, bulk-prefilter the candidate versions, insert the survivors
        in ascending rank, then flush the delta entries."""
        tab = self.tab
        order = np.lexsort((tab.edge_id, tab.ct, -tab.ts_to))
        e_sorted = tab.edge_id[order].astype(np.int64)
        c_sorted = tab.ct[order].astype(np.int64)
        neg_ts = -tab.ts_to[order].astype(np.int64)   # ascending
        R = order.shape[0]
        done = 0
        for ts in range(tab.t_max, 0, -1):
            self._cur_ts = ts
            lo = int(np.searchsorted(neg_ts, -ts, side="left"))
            hi = int(np.searchsorted(neg_ts, -ts, side="right"))
            if hi > lo:
                ce, cc = e_sorted[lo:hi], c_sorted[lo:hi]
                acc = self._accept_mask(ce, cc)
                for e, c in zip(ce[acc].tolist(), cc[acc].tolist()):
                    self.insert(e, c)
                done = hi
            self.flush(ts)
        if done != R:
            raise ForestInvariantError(
                f"processed {done} of {R} version records")
        return self


class FastIncrementalBuilder(IncrementalBuilder):
    """`IncrementalBuilder` with the per-node hot state in Python lists.

    The zipper cascade and the findInsertion climb are scalar pointer
    chases — a few reads/writes of parent/child/rank per hop, tens of
    hops per insert. Numpy scalar indexing pays ~5x a list access for
    each of them, and the ``via`` slot bookkeeping allocated a dict per
    insert; this subclass keeps ``parent/child0/child1/rank/in`` as plain
    lists during `run` and resolves slots by direct child comparison.
    The numpy node arrays that the MSF prefilter and `pack_index` read
    (``n_u/n_v/n_ct/n_edge/n_rank/n_live_*``) stay maintained throughout,
    and `run` writes the list state back into ``n_parent``/``n_child`` so
    the finished builder is indistinguishable from the base class.

    The construction order is identical — same prefilter, same
    ascending-rank inserts, same flush — so the recorded entries are
    bit-identical to the base builder's (per-ts forests are unique, and
    node ids are assigned in the same insertion order). Tests assert
    exactly this; the stratified plane (`build_stratified_index`) uses
    the fast builder while the per-k oracle path keeps the base class.
    """

    def __init__(self, g, tab: CoreTimeTable, *, prefilter: bool = True):
        super().__init__(g, tab, prefilter=prefilter)
        R = self._cap
        self._parent_l: list[int] = [NONE] * R
        self._child0_l: list[int] = [NONE] * R
        self._child1_l: list[int] = [NONE] * R
        self._rank_l: list[int] = [0] * R
        self._in_l: list[bool] = [False] * R
        # last-recorded (l, r, p) per node as lists (-2 = never recorded)
        self._last_l: list[int] = [-2] * (3 * R)

    def _new_node(self, edge_id: int, ct: int) -> int:
        x = super()._new_node(edge_id, ct)
        self._rank_l[x] = int(self.n_rank[x])
        return x

    def _find_side(self, vert: int, rk: int):
        keys, nodes = self._inc_key[vert], self._inc_node[vert]
        i = bisect.bisect_left(keys, rk)
        if i > 0:
            child = nodes[i - 1]
            parent, rank = self._parent_l, self._rank_l
            p = parent[child]
            while p != NONE and rank[p] < rk:
                child = p
                p = parent[child]
            if p == NONE:
                return child, NONE, NONE
            if self._child0_l[p] == child:
                return child, p, 0
            if self._child1_l[p] != child:
                raise ForestInvariantError(
                    f"node {child} is not a child of {p}")
            return child, p, 1
        if i >= len(keys):
            return NONE, NONE, NONE
        attach = nodes[i]
        via = 0 if self.n_u[attach] == vert else 1
        taken = self._child0_l[attach] if via == 0 else self._child1_l[attach]
        if taken != NONE:
            raise ForestInvariantError(
                f"entry slot {via} of node {attach} unexpectedly taken")
        return NONE, attach, via

    def insert(self, edge_id: int, ct: int) -> int | None:
        g = self.g
        uu, vv = int(g.src[edge_id]), int(g.dst[edge_id])
        if uu == vv:
            return None
        rk = int(np.int64(ct) * self._stride + edge_id)
        l, eu, va = self._find_side(uu, rk)
        r, ev, vb = self._find_side(vv, rk)
        if l != NONE and l == r:
            return None

        x = self._new_node(edge_id, ct)
        parent, c0, c1 = self._parent_l, self._child0_l, self._child1_l
        rank = self._rank_l
        dirty = self._dirty_nodes
        self._in_l[x] = True
        c0[x] = l
        c1[x] = r
        if l != NONE:
            parent[l] = x
            dirty.add(l)
        if r != NONE:
            parent[r] = x
            dirty.add(r)
        self._inc_add(uu, x, rk)
        self._inc_add(vv, x, rk)
        self._live_add(x)
        dirty.add(x)

        # zipper merge; (a, va) and (b, vb) are the chain heads and the
        # slot each will hand to the node hung beneath it
        cur, a, b = x, eu, ev
        expired = None
        while True:
            if a == NONE and b == NONE:
                parent[cur] = NONE
                break
            if a == NONE or b == NONE:
                t, s = (a, va) if a != NONE else (b, vb)
                parent[cur] = t
                if s == 0:
                    c0[t] = cur
                else:
                    c1[t] = cur
                dirty.add(t)
                break
            if a == b:
                # Lemma 5.7: the meeting node is the cycle's LCA -> expired
                expired = a
                p = parent[a]
                parent[cur] = p
                if p != NONE:
                    if c0[p] == a:
                        c0[p] = cur
                    elif c1[p] == a:
                        c1[p] = cur
                    else:
                        raise ForestInvariantError(
                            f"node {a} is not a child of {p}")
                    dirty.add(p)
                self._delete_node(a)
                break
            if rank[a] < rank[b]:
                lo, vlo = a, va
            else:
                lo, vlo, b, vb = b, vb, a, va
            nxt = parent[lo]
            parent[cur] = lo
            if vlo == 0:
                c0[lo] = cur
            else:
                c1[lo] = cur
            dirty.add(lo)
            if nxt != NONE:
                if c0[nxt] == lo:
                    va = 0
                elif c1[nxt] == lo:
                    va = 1
                else:
                    raise ForestInvariantError(
                        f"node {lo} is not a child of {nxt}")
            cur, a = lo, nxt
        return expired

    def _delete_node(self, x: int):
        self._in_l[x] = False
        self.n_live_from[x] = self._cur_ts + 1
        self._inc_remove(int(self.n_u[x]), x)
        self._inc_remove(int(self.n_v[x]), x)
        self._live_remove(x)
        self._dirty_nodes.discard(x)

    def flush(self, ts: int):
        last = self._last_l
        in_l, c0, c1 = self._in_l, self._child0_l, self._child1_l
        parent = self._parent_l
        ent_node, ent_ts = self.ent_node, self.ent_ts
        ent_l, ent_r, ent_p = self.ent_l, self.ent_r, self.ent_p
        for x in self._dirty_nodes:
            if not in_l[x]:
                continue
            l, r, p = c0[x], c1[x], parent[x]
            j = 3 * x
            if last[j] != l or last[j + 1] != r or last[j + 2] != p:
                last[j] = l
                last[j + 1] = r
                last[j + 2] = p
                ent_node.append(x)
                ent_ts.append(ts)
                ent_l.append(l)
                ent_r.append(r)
                ent_p.append(p)
        for vert in self._dirty_verts:
            lst = self._inc_node[vert]
            node = lst[0] if lst else NONE
            if self._last_vent[vert] != node:
                self._last_vent[vert] = node
                self.vent_vert.append(vert)
                self.vent_ts.append(ts)
                self.vent_node.append(node)
        self._dirty_nodes.clear()
        self._dirty_verts.clear()

    def run(self):
        super().run()
        # write the list state back so the finished builder's numpy node
        # arrays match the base class bit for bit
        N = self.num_nodes
        if N:
            self.n_parent[:N] = self._parent_l[:N]
            self.n_child[:N, 0] = self._child0_l[:N]
            self.n_child[:N, 1] = self._child1_l[:N]
            self.n_in[:N] = self._in_l[:N]
            self._last[:N] = np.asarray(
                self._last_l[:3 * N], np.int32).reshape(N, 3)
        return self
