"""Benchmark aggregator: one section per paper table/figure + engine benches.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import time


def _emit(title, header, rows):
    print(f"\n== {title} ==")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small workloads only (CI)")
    args = ap.parse_args(argv)

    from . import bench_construction as bc
    from . import bench_paper as bp
    from . import bench_engine as be
    from . import bench_retention as br
    from . import bench_streaming as bs

    workloads = ["fb_like", "cm_like"] if args.fast else bp.WORKLOADS

    t0 = time.time()
    _emit("Construction plane: PR-1 vs batched (cold, same run)",
          ["workload", "k", "pr1_core_s", "pr1_forest_s", "pr1_total_s",
           "batched_core_s", "batched_forest_s", "batched_total_s", "speedup"],
          bc.bench_construction_plane(workloads))
    _emit("Index space (Fig 4)",
          ["workload", "k", "pecb_bytes", "ctmsf_bytes", "ef_bytes", "ef/pecb"],
          bp.bench_index_size(workloads))
    _emit("Construction time (Fig 5)",
          ["workload", "k", "pecb_s", "ctmsf_s", "ef_s", "ef/pecb"],
          bp.bench_construction(workloads))
    _emit("Query time, 1000 random queries (Fig 6)",
          ["workload", "k", "pecb_us", "ctmsf_us", "ef_us"],
          bp.bench_query(workloads))
    _emit("Impact of k (Figs 7-9)",
          ["workload", "frac", "k", "pecb_bytes", "ef_bytes", "pecb_s", "ef_s",
           "pecb_us", "ef_us"],
          bp.bench_vary_k("cm_like"))
    _emit("Fine-grained timestamps (Figs 10-12)",
          ["workload", "t_max", "pecb_s", "ef_s", "pecb_bytes", "ef_bytes",
           "pecb_us", "ef_us"],
          bp.bench_fine_grained("fb_like", factor=4 if args.fast else 8))
    _emit("Batched TCCS engine (beyond paper; CPU-interpret caveat in module doc)",
          ["workload", "batch", "batched_us_per_q", "alg1_us_per_q", "speedup"],
          be.bench_batch_query("fb_like", batches=(32, 128) if args.fast else (32, 128, 512)))
    _emit("Serving engine offered-load sweep + window-sweep scenario (beyond paper)",
          ["workload", "k", "offered_qps", "queries", "achieved_qps",
           "p50_ms", "p95_ms", "p99_ms", "device_batches", "host_batches"],
          be.bench_engine_load_sweep(
              "fb_like",
              loads=(2000, 0) if args.fast else (1000, 4000, 16000, 0),
              n_q=512 if args.fast else 2048))
    _emit("Streaming refresh vs cold rebuild (beyond paper; equality "
          "asserted before reporting)",
          ["workload", "k", "suffix_edges", "refresh_tab_s",
           "refresh_index_s", "refresh_device_s", "refresh_total_s",
           "cold_total_s", "speedup", "device_uploaded_bytes",
           "device_reused_bytes"],
          # the fast job smoke-runs the small workload without the em_like
          # 5x floor (CI machines are noisy); the full run asserts it
          bs.bench_refresh(("fb_like",) if args.fast else ("em_like",),
                           assert_speedup=not args.fast))
    _emit("Retention: shrink vs cold rebuild (beyond paper; equality "
          "asserted before reporting)",
          ["workload", "k", "t_cut", "expired_edges", "shrink_tab_s",
           "shrink_index_s", "shrink_device_s", "shrink_total_s",
           "cold_total_s", "speedup", "device_freed_bytes"],
          # fast job smoke-runs the small workload without the em_like 3x
          # floor (CI machines are noisy); the full run asserts it
          br.bench_shrink(("fb_like",) if args.fast else ("em_like",),
                          assert_speedup=not args.fast))
    _emit("Retention: rolling-window steady state (beyond paper; bounded "
          "nbytes asserted across append+expire cycles)",
          ["workload", "k", "window", "cycle", "t_max", "index_bytes",
           "tab_bytes", "cache_entries", "trim_s"],
          br.bench_rolling("fb_like" if args.fast else "em_like"))
    _emit("Query availability during streaming refresh (beyond paper)",
          ["workload", "k", "suffix_edges", "queries_during_refresh",
           "refresh_s", "mean_ms", "worst_ms"],
          bs.bench_availability("fb_like" if args.fast else "em_like"))
    _emit("Pallas kernel micro (interpret mode vs jnp ref)",
          ["kernel", "pallas_interpret_ms", "jnp_ref_ms"],
          be.bench_kernels())
    print(f"\n[benchmarks done in {time.time()-t0:.1f}s; CSVs in results/bench/]")


if __name__ == "__main__":
    main()
