"""Benchmark aggregator: one section per paper table/figure + engine benches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--bench-json DIR]

Besides the stdout tables and per-bench CSVs (results/bench/), every run
distills each area into a committed, schema-stable perf-trajectory
artifact ``BENCH_<area>.json`` (see benchmarks/artifacts.py): key metrics
with machine-normalized values, plus the raw rows. ``--bench-json ''``
skips the artifacts.
"""

from __future__ import annotations

import argparse
import statistics
import time


def _emit(title, header, rows):
    print(f"\n== {title} ==")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return header, rows


def _col(rows, header, name):
    """One column of a rows/header table as floats (non-numeric skipped)."""
    i = header.index(name)
    out = []
    for r in rows:
        try:
            out.append(float(r[i]))
        except (TypeError, ValueError):
            pass
    return out


def _mean(rows, header, name):
    vals = _col(rows, header, name)
    return statistics.fmean(vals) if vals else 0.0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small workloads only (CI)")
    ap.add_argument("--bench-json", metavar="DIR", default=".",
                    help="directory for BENCH_<area>.json perf-trajectory "
                         "artifacts (default: repo root; '' disables)")
    args = ap.parse_args(argv)

    from . import bench_construction as bc
    from . import bench_paper as bp
    from . import bench_engine as be
    from . import bench_retention as br
    from . import bench_store as bst
    from . import bench_streaming as bs

    workloads = ["fb_like", "cm_like"] if args.fast else bp.WORKLOADS

    t0 = time.time()
    cons_h, cons_r = _emit(
        "Construction plane: PR-1 vs batched (cold, same run)",
        ["workload", "k", "pr1_core_s", "pr1_forest_s", "pr1_total_s",
         "batched_core_s", "batched_forest_s", "batched_total_s", "speedup"],
        bc.bench_construction_plane(workloads))
    strat_h, strat_r = _emit(
        "Stratified construction: one |K|-build vs per-k builds "
        "(equality asserted per stratum before reporting)",
        ["workload", "n_ks", "ks", "perk_build_s", "strat_build_s",
         "build_speedup", "perk_mb", "strat_mb", "bytes_ratio"],
        # the fast job smoke-runs the small workload without the em_like
        # 3x / 2x floors (CI machines are noisy); the full run asserts both
        bc.bench_stratified_construction(
            "fb_like" if args.fast else "em_like",
            assert_floors=not args.fast))
    _emit("Index space (Fig 4)",
          ["workload", "k", "pecb_bytes", "ctmsf_bytes", "ef_bytes", "ef/pecb"],
          bp.bench_index_size(workloads))
    fig5_h, fig5_r = _emit(
        "Construction time (Fig 5)",
        ["workload", "k", "pecb_s", "ctmsf_s", "ef_s", "ef/pecb"],
        bp.bench_construction(workloads))
    fig6_h, fig6_r = _emit(
        "Query time, 1000 random queries (Fig 6)",
        ["workload", "k", "pecb_us", "ctmsf_us", "ef_us"],
        bp.bench_query(workloads))
    _emit("Impact of k (Figs 7-9)",
          ["workload", "frac", "k", "pecb_bytes", "ef_bytes", "pecb_s", "ef_s",
           "pecb_us", "ef_us"],
          bp.bench_vary_k("cm_like"))
    _emit("Fine-grained timestamps (Figs 10-12)",
          ["workload", "t_max", "pecb_s", "ef_s", "pecb_bytes", "ef_bytes",
           "pecb_us", "ef_us"],
          bp.bench_fine_grained("fb_like", factor=4 if args.fast else 8))
    bq_h, bq_r = _emit(
        "Batched TCCS engine (beyond paper; CPU-interpret caveat in module doc)",
        ["workload", "batch", "batched_us_per_q", "alg1_us_per_q", "speedup"],
        be.bench_batch_query("fb_like",
                             batches=(32, 128) if args.fast else (32, 128, 512)))
    load_h, load_r = _emit(
        "Serving engine offered-load sweep + window-sweep scenario (beyond paper)",
        ["workload", "k", "offered_qps", "queries", "achieved_qps",
         "p50_ms", "p95_ms", "p99_ms", "device_batches", "host_batches"],
        be.bench_engine_load_sweep(
            "fb_like",
            loads=(2000, 0) if args.fast else (1000, 4000, 16000, 0),
            n_q=512 if args.fast else 2048))
    trace_h, trace_r = _emit(
        "Serving-plane tracing overhead (DESIGN.md §11 acceptance)",
        ["workload", "k", "arm", "queries", "qps", "p99_ms",
         "chain_coverage", "spans", "dropped"],
        # the fast job smoke-runs the A/B without the 5% p99 gate (CI
        # machines are noisy); chain coverage >= 95% is asserted always
        be.bench_trace_overhead("fb_like", n_q=256 if args.fast else 512,
                                reps=1 if args.fast else 2,
                                assert_overhead=not args.fast))
    strm_h, strm_r = _emit(
        "Streaming refresh vs cold rebuild (beyond paper; equality "
        "asserted before reporting)",
        ["workload", "k", "suffix_edges", "refresh_tab_s",
         "refresh_index_s", "refresh_device_s", "refresh_total_s",
         "cold_total_s", "speedup", "device_uploaded_bytes",
         "device_reused_bytes"],
        # the fast job smoke-runs the small workload without the em_like
        # 5x floor (CI machines are noisy); the full run asserts it
        bs.bench_refresh(("fb_like",) if args.fast else ("em_like",),
                         assert_speedup=not args.fast))
    shr_h, shr_r = _emit(
        "Retention: shrink vs cold rebuild (beyond paper; equality "
        "asserted before reporting)",
        ["workload", "k", "t_cut", "expired_edges", "shrink_tab_s",
         "shrink_index_s", "shrink_device_s", "shrink_total_s",
         "cold_total_s", "speedup", "device_freed_bytes"],
        # fast job smoke-runs the small workload without the em_like 3x
        # floor (CI machines are noisy); the full run asserts it
        br.bench_shrink(("fb_like",) if args.fast else ("em_like",),
                        assert_speedup=not args.fast))
    roll_h, roll_r = _emit(
        "Retention: rolling-window steady state (beyond paper; bounded "
        "nbytes asserted across append+expire cycles)",
        ["workload", "k", "window", "cycle", "t_max", "index_bytes",
         "tab_bytes", "cache_entries", "trim_s"],
        br.bench_rolling("fb_like" if args.fast else "em_like"))
    avail_h, avail_r = _emit(
        "Query availability during streaming refresh (beyond paper)",
        ["workload", "k", "suffix_edges", "queries_during_refresh",
         "refresh_s", "mean_ms", "worst_ms"],
        bs.bench_availability("fb_like" if args.fast else "em_like"))
    warm_h, warm_r = _emit(
        "Persistent store: warm restart vs cold build (beyond paper; "
        "equality asserted before reporting)",
        ["workload", "n_ks", "stored_bytes", "cold_total_s", "warm_open_s",
         "warm_device_s", "warm_total_s", "speedup"],
        # fast job smoke-runs the small workload without the em_like
        # sub-second / 10x floors (CI machines are noisy); the full run
        # asserts both
        bst.bench_warm_restart(("fb_like",) if args.fast else ("em_like",),
                               assert_speedup=not args.fast))
    dlt_h, dlt_r = _emit(
        "Persistent store: delta vs full commit of a suffix epoch",
        ["workload", "n_ks", "suffix_edges", "full_bytes", "full_s",
         "delta_bytes", "delta_s", "delta_bytes_ratio"],
        bst.bench_delta(("fb_like",) if args.fast else ("em_like",)))
    _emit("Pallas kernel micro (interpret mode vs jnp ref)",
          ["kernel", "pallas_interpret_ms", "jnp_ref_ms"],
          be.bench_kernels())

    if args.bench_json:
        write_artifacts(args.bench_json, args.fast, {
            "construction": (cons_h, cons_r, fig5_h, fig5_r,
                             strat_h, strat_r),
            "engine": (bq_h, bq_r, load_h, load_r, trace_h, trace_r,
                       fig6_h, fig6_r),
            "streaming": (strm_h, strm_r, avail_h, avail_r),
            "retention": (shr_h, shr_r, roll_h, roll_r),
            "sweep": (load_h, load_r),
            "store": (warm_h, warm_r, dlt_h, dlt_r),
        })
    print(f"\n[benchmarks done in {time.time()-t0:.1f}s; CSVs in results/bench/]")


def write_artifacts(out_dir: str, fast: bool, raw: dict) -> None:
    """Distill the collected rows into one BENCH_<area>.json per area,
    validate each on the way out, and print the paths."""
    from .artifacts import machine_info, validate_bench_files, write_bench_json

    machine = machine_info()
    paths = []

    cons_h, cons_r, fig5_h, fig5_r, strat_h, strat_r = raw["construction"]
    paths.append(write_bench_json(out_dir, "construction", {
        "batched_total_s": (_mean(cons_r, cons_h, "batched_total_s"), "s"),
        "speedup_vs_pr1": (_mean(cons_r, cons_h, "speedup"), "x"),
        "pecb_build_s": (_mean(fig5_r, fig5_h, "pecb_s"), "s"),
        "ef_build_s": (_mean(fig5_r, fig5_h, "ef_s"), "s"),
        "stratified_build_s": (_mean(strat_r, strat_h, "strat_build_s"),
                               "s"),
        "stratified_build_speedup": (
            _mean(strat_r, strat_h, "build_speedup"), "x"),
        "stratified_bytes_ratio": (
            _mean(strat_r, strat_h, "bytes_ratio"), "x"),
    }, {"construction_plane": (cons_h, cons_r),
        "construction_fig5": (fig5_h, fig5_r),
        "construction_stratified": (strat_h, strat_r)}, machine, fast))

    bq_h, bq_r, load_h, load_r, trace_h, trace_r, fig6_h, fig6_r = raw["engine"]
    # the window-sweep scenario rows share the load-sweep table, labeled
    # perwin_w{W} / sweep_w{W} in offered_qps; split them out
    oq = load_h.index("offered_qps")
    sweep_rows = [r for r in load_r if str(r[oq]).startswith(("perwin_",
                                                             "sweep_"))]
    pure_load = [r for r in load_r if r not in sweep_rows]
    open_rows = [r for r in pure_load if r[oq] == "open"]
    open_row = open_rows[0] if open_rows else pure_load[-1]
    mixed_rows = [r for r in pure_load if r[oq] == "mixed_k"]
    mixed_row = mixed_rows[0] if mixed_rows else open_row
    traced = [r for r in trace_r if r[trace_h.index("arm")] == "traced"]
    untraced = [r for r in trace_r if r[trace_h.index("arm")] == "untraced"]
    p99_i, qps_i = trace_h.index("p99_ms"), trace_h.index("qps")
    ratio = (float(traced[0][p99_i]) / float(untraced[0][p99_i])
             if untraced and float(untraced[0][p99_i]) > 0 else 1.0)
    paths.append(write_bench_json(out_dir, "engine", {
        "open_loop_qps": (float(open_row[load_h.index("achieved_qps")]), "qps"),
        "open_loop_p99_ms": (float(open_row[load_h.index("p99_ms")]), "ms"),
        "mixed_k_qps": (float(mixed_row[load_h.index("achieved_qps")]),
                        "qps"),
        "mixed_k_p99_ms": (float(mixed_row[load_h.index("p99_ms")]), "ms"),
        "batch_query_us_per_q": (min(_col(bq_r, bq_h, "batched_us_per_q")),
                                 "us"),
        "alg1_us_per_q": (_mean(fig6_r, fig6_h, "pecb_us"), "us"),
        "traced_qps": (float(traced[0][qps_i]), "qps"),
        "trace_overhead_p99_ratio": (ratio, "x"),
        "span_chain_coverage": (
            float(traced[0][trace_h.index("chain_coverage")]), "frac"),
    }, {"load_sweep": (load_h, pure_load), "batch_query": (bq_h, bq_r),
        "trace_overhead": (trace_h, trace_r)}, machine, fast))

    strm_h, strm_r, avail_h, avail_r = raw["streaming"]
    paths.append(write_bench_json(out_dir, "streaming", {
        "refresh_total_s": (_mean(strm_r, strm_h, "refresh_total_s"), "s"),
        "cold_total_s": (_mean(strm_r, strm_h, "cold_total_s"), "s"),
        "refresh_speedup": (_mean(strm_r, strm_h, "speedup"), "x"),
        "query_mean_ms_during_refresh": (_mean(avail_r, avail_h, "mean_ms"),
                                         "ms"),
        "query_worst_ms_during_refresh": (_mean(avail_r, avail_h, "worst_ms"),
                                          "ms"),
    }, {"refresh": (strm_h, strm_r), "availability": (avail_h, avail_r)},
        machine, fast))

    shr_h, shr_r, roll_h, roll_r = raw["retention"]
    paths.append(write_bench_json(out_dir, "retention", {
        "shrink_total_s": (_mean(shr_r, shr_h, "shrink_total_s"), "s"),
        "cold_total_s": (_mean(shr_r, shr_h, "cold_total_s"), "s"),
        "shrink_speedup": (_mean(shr_r, shr_h, "speedup"), "x"),
        "rolling_trim_s": (_mean(roll_r, roll_h, "trim_s"), "s"),
        "rolling_index_bytes_max": (max(_col(roll_r, roll_h, "index_bytes")),
                                    "bytes"),
    }, {"shrink": (shr_h, shr_r), "rolling": (roll_h, roll_r)},
        machine, fast))

    warm_h, warm_r, dlt_h, dlt_r = raw["store"]
    paths.append(write_bench_json(out_dir, "store", {
        "warm_restart_s": (_mean(warm_r, warm_h, "warm_total_s"), "s"),
        "cold_build_s": (_mean(warm_r, warm_h, "cold_total_s"), "s"),
        "warm_speedup": (_mean(warm_r, warm_h, "speedup"), "x"),
        "stored_bytes": (_mean(warm_r, warm_h, "stored_bytes"), "bytes"),
        "delta_commit_bytes_ratio": (_mean(dlt_r, dlt_h, "delta_bytes_ratio"),
                                     "frac"),
        "delta_commit_s": (_mean(dlt_r, dlt_h, "delta_s"), "s"),
    }, {"warm_restart": (warm_h, warm_r), "delta_commit": (dlt_h, dlt_r)},
        machine, fast))

    sw_h, sw_r = raw["sweep"]
    qps_i = sw_h.index("achieved_qps")
    per_win = [r for r in sw_r if str(r[oq]).startswith("perwin_")]
    one_call = [r for r in sw_r if str(r[oq]).startswith("sweep_")]
    perwin_qps = float(per_win[0][qps_i]) if per_win else 0.0
    sweep_qps = float(one_call[0][qps_i]) if one_call else 0.0
    paths.append(write_bench_json(out_dir, "sweep", {
        "sweep_windows_per_s": (sweep_qps, "qps"),
        "perwin_windows_per_s": (perwin_qps, "qps"),
        "sweep_speedup": (sweep_qps / perwin_qps if perwin_qps else 0.0, "x"),
    }, {"window_sweep": (sw_h, per_win + one_call)}, machine, fast))

    validate_bench_files(out_dir)   # what we wrote must re-load clean
    print("\n[bench artifacts]")
    for p in paths:
        print(f"  {p}")


if __name__ == "__main__":
    main()
