"""Beyond-paper engine benchmarks: batched TCCS throughput + kernel micro.

CPU caveat recorded in the CSV: the batched engine's advantage is a TPU
property (dense (B,N) propagation on VPU/MXU vs pointer chasing); on this
container the Pallas kernels run in interpret mode and the dense engine
pays Python dispatch, so absolute numbers here only validate correctness
plumbing + scaling shape, not the TPU speedup claim.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from .common import default_k, random_queries, timed, workload, write_csv
from repro.core.core_time import edge_core_times
from repro.core.pecb_index import build_pecb_index
from repro.core.batch_query import to_device, batch_query
from repro.core.query_api import TCCSQuery, WindowSweep
from repro.serving import EngineConfig, IndexRegistry, ServingEngine


def bench_batch_query(name: str = "fb_like", batches=(32, 128, 512)):
    g = workload(name)
    k = default_k(name)
    idx = build_pecb_index(g, k, edge_core_times(g, k))
    dix = to_device(idx)
    rows = []
    queries = random_queries(g, max(batches), seed=3)
    u = jnp.asarray([q[0] for q in queries], jnp.int32)
    ts = jnp.asarray([q[1] for q in queries], jnp.int32)
    te = jnp.asarray([q[2] for q in queries], jnp.int32)

    # sequential Algorithm 1 reference
    t0 = time.perf_counter()
    for (uu, a, b) in queries[:256]:
        idx._component_vertices(uu, a, b)
    seq_us = (time.perf_counter() - t0) / 256 * 1e6

    for B in batches:
        fn = jax.jit(batch_query)
        out = fn(dix, u[:B], ts[:B], te[:B])
        out.block_until_ready()          # compile
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            out = fn(dix, u[:B], ts[:B], te[:B])
        out.block_until_ready()
        us_per_q = (time.perf_counter() - t0) / (reps * B) * 1e6
        rows.append([name, B, round(us_per_q, 2), round(seq_us, 2),
                     round(seq_us / us_per_q, 3)])
    write_csv("batch_query.csv",
              ["workload", "batch", "batched_us_per_q", "alg1_us_per_q",
               "speedup"], rows)
    return rows


def bench_engine_load_sweep(name: str = "fb_like",
                            loads=(1000, 4000, 16000, 0),
                            n_q: int = 2048, seed: int = 9):
    """Offered-load sweep through the full serving engine.

    Replays ``n_q`` random queries at each offered load (queries/s; 0 =
    open loop, submit as fast as the engine accepts) through a fresh
    ServingEngine sharing one warm index registry, and records achieved
    throughput plus end-to-end latency percentiles per load — the
    throughput/latency curve a capacity planner reads. The result cache is
    disabled so every query pays its true execution path.

    CSV: engine_load_sweep.csv
    """
    g = workload(name)
    k = default_k(name)
    registry = IndexRegistry(capacity=4)
    registry.register_graph(name, g)
    queries = random_queries(g, n_q, seed=seed)
    rows = bench_window_sweep(name, registry=registry)
    for load in loads:
        cfg = EngineConfig(max_batch=256, flush_ms=2.0, cache_capacity=0)
        with ServingEngine(cfg, registry=registry) as eng:
            eng.warmup(name)
            t0 = time.perf_counter()
            futures = []
            if load:
                period = 1.0 / load
                for i, q in enumerate(queries):
                    target = t0 + i * period
                    delay = target - time.perf_counter()
                    if delay > 0:
                        time.sleep(delay)
                    futures.append(eng.submit_spec(
                        name, TCCSQuery(*q, k)))
            else:
                for i in range(0, len(queries), cfg.max_batch):
                    futures += eng.submit_specs(
                        name, [TCCSQuery(u, ts, te, k) for (u, ts, te)
                               in queries[i:i + cfg.max_batch]])
            eng.flush()
            for f in futures:
                f.result(timeout=300)
            dt = time.perf_counter() - t0
            snap = eng.stats()
            e2e = snap["engine"]["latency"]["e2e"]
            counters = snap["engine"]["counters"]
            rows.append([
                name, k, load if load else "open", n_q,
                round(n_q / dt, 1),
                round(e2e["p50_ms"], 3), round(e2e["p95_ms"], 3),
                round(e2e["p99_ms"], 3),
                counters.get("device_batches", 0),
                counters.get("host_batches", 0),
            ])
    # mixed-k offered load (PR-9 tentpole): the same open-loop replay
    # with k drawn per query from the handle's supported strata — one
    # stratified handle, one device program per bucket shape, zero
    # per-k registry entries
    h = registry.get(name)
    krng = np.random.default_rng(seed + 1)
    kq = [int(krng.choice(h.supported_ks)) for _ in queries]
    cfg = EngineConfig(max_batch=256, flush_ms=2.0, cache_capacity=0)
    with ServingEngine(cfg, registry=registry) as eng:
        eng.warmup(name)
        t0 = time.perf_counter()
        futures = []
        for i in range(0, len(queries), cfg.max_batch):
            futures += eng.submit_specs(
                name, [TCCSQuery(u, ts, te, kk)
                       for (u, ts, te), kk in
                       zip(queries[i:i + cfg.max_batch],
                           kq[i:i + cfg.max_batch])])
        eng.flush()
        for f in futures:
            f.result(timeout=300)
        dt = time.perf_counter() - t0
        snap = eng.stats()
        e2e = snap["engine"]["latency"]["e2e"]
        counters = snap["engine"]["counters"]
        rows.append([
            name, "mix", "mixed_k", n_q,
            round(n_q / dt, 1),
            round(e2e["p50_ms"], 3), round(e2e["p95_ms"], 3),
            round(e2e["p99_ms"], 3),
            counters.get("device_batches", 0),
            counters.get("host_batches", 0),
        ])
    write_csv("engine_load_sweep.csv",
              ["workload", "k", "offered_qps", "queries", "achieved_qps",
               "p50_ms", "p95_ms", "p99_ms", "device_batches", "host_batches"],
              rows)
    return rows


def bench_window_sweep(name: str = "fb_like", W: int = 64, seed: int = 11,
                       registry: IndexRegistry | None = None):
    """Window-sweep scenario (query API v2): one vertex, ``W`` sliding
    windows — the contact-tracing trajectory query.

    Compares the pre-v2 client pattern (``W`` independent single-query
    round trips, each paying batcher deadline + its own route) against ONE
    ``WindowSweep`` engine call (a single ``window_sweep`` device launch
    for all cache-missing windows). Results are asserted identical; rows
    land in the offered-load CSV with offered_qps labels ``perwin_w{W}`` /
    ``sweep_w{W}``.
    """
    g = workload(name)
    k = default_k(name)
    if registry is None:
        registry = IndexRegistry(capacity=4)
        registry.register_graph(name, g)
    rng = np.random.default_rng(seed)
    u = int(rng.integers(0, g.n))
    span = max(2, g.t_max // 10)
    starts = np.linspace(1, max(1, g.t_max - span), W).astype(int)
    windows = [(int(s), min(int(s) + span, g.t_max)) for s in starts]
    rows = []

    # -- W independent submits (the pre-v2 client loop) -------------------
    cfg = EngineConfig(max_batch=256, flush_ms=2.0, cache_capacity=0)
    with ServingEngine(cfg, registry=registry) as eng:
        eng.warmup(name)
        t0 = time.perf_counter()
        per_win = [eng.submit_spec(name, TCCSQuery(u, ts, te, k))
                      .result(timeout=300).vertices
                   for (ts, te) in windows]
        dt_perwin = time.perf_counter() - t0
        snap = eng.stats()
        e2e = snap["engine"]["latency"]["e2e"]
        counters = snap["engine"]["counters"]
        rows.append([name, k, f"perwin_w{W}", W, round(W / dt_perwin, 1),
                     round(e2e["p50_ms"], 3), round(e2e["p95_ms"], 3),
                     round(e2e["p99_ms"], 3),
                     counters.get("device_batches", 0),
                     counters.get("host_batches", 0)])

    # -- one WindowSweep call --------------------------------------------
    with ServingEngine(cfg, registry=registry) as eng:
        # compile outside the measurement (the swept k's stratum only)
        eng.warmup(name, sweep=True, sweep_ks=(k,))
        t0 = time.perf_counter()
        swept = eng.sweep(name, WindowSweep(u, k, windows), timeout=300)
        dt_sweep = time.perf_counter() - t0
        snap = eng.stats()
        e2e = snap["engine"]["latency"]["sweep_exec"]
        counters = snap["engine"]["counters"]
        rows.append([name, k, f"sweep_w{W}", W, round(W / dt_sweep, 1),
                     round(e2e["p50_ms"], 3), round(e2e["p95_ms"], 3),
                     round(e2e["p99_ms"], 3),
                     counters.get("sweep_launches", 0),
                     counters.get("host_batches", 0)])

    for res, want in zip(swept, per_win):
        assert res.vertices == want, "sweep/per-window mismatch"
    # the acceptance bar: one sweep launch beats W independent submits
    assert dt_sweep < dt_perwin, (dt_sweep, dt_perwin)
    print(f"[sweep] {name} k={k} u={u} W={W}: per-window {dt_perwin:.3f}s "
          f"vs sweep {dt_sweep:.3f}s ({dt_perwin/dt_sweep:.1f}x)")
    return rows


def bench_trace_overhead(name: str = "fb_like", n_q: int = 512,
                         seed: int = 13, reps: int = 2,
                         assert_overhead: bool = True):
    """Tracing-overhead A/B (DESIGN.md §11 acceptance): replay the same
    open-loop query stream through an untraced and a traced engine
    sharing one warm registry (cache off so every query pays its real
    path), best-of-``reps`` per arm.

    Asserts on every run that >= 95% of completed queries carry the full
    span chain (query -> queue -> route -> execute) and that the traced
    arm's Chrome trace export validates; on full runs additionally
    asserts traced p99 <= 1.05x untraced p99. Rows: one per arm,
    ``[workload, k, arm, queries, qps, p99_ms, chain_coverage, spans,
    dropped]``; the traced arm's export lands in
    ``results/bench/trace_engine.json``.
    """
    from collections import defaultdict

    from repro.obs.export import validate_chrome_trace
    from .common import RESULTS_DIR

    g = workload(name)
    k = default_k(name)
    registry = IndexRegistry(capacity=4)
    registry.register_graph(name, g)
    queries = random_queries(g, n_q, seed=seed)

    def run_arm(trace: bool):
        best = None
        for _ in range(max(1, reps)):
            cfg = EngineConfig(max_batch=256, flush_ms=2.0,
                               cache_capacity=0, trace=trace)
            with ServingEngine(cfg, registry=registry) as eng:
                eng.warmup(name)
                t0 = time.perf_counter()
                futures = []
                for i in range(0, len(queries), cfg.max_batch):
                    futures += eng.submit_specs(
                        name, [TCCSQuery(u, ts, te, k) for (u, ts, te)
                               in queries[i:i + cfg.max_batch]])
                eng.flush()
                results = [f.result(timeout=300) for f in futures]
                dt = time.perf_counter() - t0
                p99 = eng.stats()["engine"]["latency"]["e2e"]["p99_ms"]
                coverage, spans, dropped, doc = 0.0, 0, 0, None
                if trace:
                    by_trace = defaultdict(set)
                    for s in eng.tracer.spans():
                        by_trace[s.trace_id].add(s.name)
                    full = sum(
                        1 for r in results
                        if {"query", "queue", "route", "execute"}
                        <= by_trace.get(r.provenance.trace_id, set()))
                    coverage = full / len(results)
                    spans = len(eng.tracer)
                    dropped = eng.tracer.dropped
                    import os
                    os.makedirs(RESULTS_DIR, exist_ok=True)
                    doc = eng.export_trace(
                        os.path.join(RESULTS_DIR, "trace_engine.json"),
                        extra={"bench": "trace_overhead", "workload": name})
                arm = (dt, p99, coverage, spans, dropped, doc)
                if best is None or arm[1] < best[1]:
                    best = arm
        return best

    dt_off, p99_off, _, _, _, _ = run_arm(False)
    dt_on, p99_on, coverage, spans, dropped, doc = run_arm(True)
    validate_chrome_trace(doc)
    assert coverage >= 0.95, f"span chain coverage {coverage:.3f} < 0.95"
    ratio = p99_on / p99_off if p99_off > 0 else 1.0
    if assert_overhead:
        assert ratio <= 1.05, (
            f"tracing p99 overhead {ratio:.3f}x exceeds 1.05x "
            f"(off={p99_off:.3f}ms on={p99_on:.3f}ms)")
    print(f"[trace-overhead] {name} k={k}: p99 off={p99_off:.3f}ms "
          f"on={p99_on:.3f}ms ({ratio:.3f}x), chain coverage "
          f"{coverage:.1%}, {spans} spans ({dropped} dropped)")
    rows = [
        [name, k, "untraced", n_q, round(n_q / dt_off, 1),
         round(p99_off, 3), "", 0, 0],
        [name, k, "traced", n_q, round(n_q / dt_on, 1),
         round(p99_on, 3), round(coverage, 4), spans, dropped],
    ]
    write_csv("trace_overhead.csv",
              ["workload", "k", "arm", "queries", "qps", "p99_ms",
               "chain_coverage", "spans", "dropped"], rows)
    return rows


def bench_kernels():
    """Per-kernel micro: interpret-mode Pallas vs jnp reference (CPU)."""
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    rows = []

    def run(tag, f_kernel, f_ref, *args):
        out = f_kernel(*args)
        jax.block_until_ready(out)
        out, dt_k = timed(lambda: jax.block_until_ready(f_kernel(*args)))
        out, dt_r = timed(lambda: jax.block_until_ready(f_ref(*args)))
        rows.append([tag, round(dt_k * 1e3, 3), round(dt_r * 1e3, 3)])

    n, m = 2000, 8000
    src = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    alive = jnp.ones(m, bool)
    run("degree_count(2k,8k)", ops.degree_count, ref.degree_count, src, dst, alive, n)

    a = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    run("matmul(512)", ops.matmul, ref.matmul, a, b)

    vals = jnp.asarray(rng.normal(size=(4096, 64)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 512, 4096), jnp.int32)
    run("segment_sum(4k,64)", lambda *xs: ops.segment_sum(*xs),
        lambda *xs: ref.segment_sum_sorted(*xs), vals, ids, 512)

    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    run("flash_attn(256)", lambda q_: ops.flash_attention(q_, q_, q_, causal=True),
        lambda q_: ref.flash_attention(q_, q_, q_, causal=True), q)

    write_csv("kernels.csv", ["kernel", "pallas_interpret_ms", "jnp_ref_ms"], rows)
    return rows
