"""Paper-figure benchmarks.

One function per paper table/figure family:
  * Figure 4 (index space)        -> bench_index_size
  * Figure 5 (construction time)  -> bench_construction
  * Figure 6 (query time)         -> bench_query
  * Figures 7-9 (impact of k)     -> bench_vary_k
Figures 10-12 (original timestamps) use the same code path on the
fine-grained variants (no day aggregation) -> bench_fine_grained.

Workloads are synthetic Table-3-shaped graphs (offline container; see
DESIGN.md §5); the claims validated are the *relative* ones the paper
makes: PECB builds 1-3 orders faster than EF, is the smallest index, and
queries stay within the same order of magnitude.
"""

from __future__ import annotations

import time

import numpy as np

from .common import (build_all, default_k, random_queries, timed, workload,
                     write_csv)

WORKLOADS = ["fb_like", "cm_like", "em_like", "mo_like", "wk_like"]
N_QUERIES = 1000


def _query_us(idx, queries) -> float:
    t0 = time.perf_counter()
    for (u, ts, te) in queries:
        idx._component_vertices(u, ts, te)
    return (time.perf_counter() - t0) / len(queries) * 1e6


def bench_index_size(workloads=WORKLOADS):
    rows = []
    for name in workloads:
        k = default_k(name)
        g, tab, pecb, ctm, ef, _ = build_all(name, k)
        rows.append([name, k, pecb.nbytes(), ctm.nbytes(), ef.nbytes(),
                     round(ef.nbytes() / pecb.nbytes(), 2)])
    write_csv("index_size.csv",
              ["workload", "k", "pecb_bytes", "ctmsf_bytes", "ef_bytes",
               "ef_over_pecb"], rows)
    return rows


def bench_construction(workloads=WORKLOADS):
    rows = []
    for name in workloads:
        k = default_k(name)
        _, _, _, _, _, times = build_all(name, k)
        rows.append([name, k, round(times["pecb_s"], 4), round(times["ctmsf_s"], 4),
                     round(times["ef_s"], 4),
                     round(times["ef_s"] / times["pecb_s"], 2)])
    write_csv("construction.csv",
              ["workload", "k", "pecb_s", "ctmsf_s", "ef_s", "ef_over_pecb"],
              rows)
    return rows


def bench_query(workloads=WORKLOADS):
    rows = []
    for name in workloads:
        k = default_k(name)
        g, tab, pecb, ctm, ef, _ = build_all(name, k)
        queries = random_queries(g, N_QUERIES)
        rows.append([name, k,
                     round(_query_us(pecb, queries), 2),
                     round(_query_us(ctm, queries), 2),
                     round(_query_us(ef, queries), 2)])
    write_csv("query_time.csv",
              ["workload", "k", "pecb_us", "ctmsf_us", "ef_us"], rows)
    return rows


def bench_vary_k(name: str = "cm_like"):
    from .common import _KMAX_CACHE
    from repro.core.kcore import k_max as kmax_fn
    g = workload(name)
    km = kmax_fn(g)
    rows = []
    for frac in (0.5, 0.6, 0.7, 0.8, 0.9):
        k = max(2, int(round(frac * km)))
        g, tab, pecb, ctm, ef, times = build_all(name, k)
        queries = random_queries(g, N_QUERIES)
        rows.append([name, frac, k,
                     pecb.nbytes(), ef.nbytes(),
                     round(times["pecb_s"], 4), round(times["ef_s"], 4),
                     round(_query_us(pecb, queries), 2),
                     round(_query_us(ef, queries), 2)])
    write_csv("vary_k.csv",
              ["workload", "frac", "k", "pecb_bytes", "ef_bytes",
               "pecb_s", "ef_s", "pecb_us", "ef_us"], rows)
    return rows


def bench_fine_grained(name: str = "fb_like", factor: int = 8):
    """Figures 10-12: finer timestamp granularity (t_max x factor).

    EF degrades superlinearly with distinct timestamps; PECB scales with
    *changes*, not timestamps.
    """
    from repro.core.temporal_graph import gen_temporal_graph, BENCH_WORKLOADS
    from repro.core.core_time import edge_core_times
    from repro.core.pecb_index import build_pecb_index
    from repro.core.ef_index import EFIndex

    cfgs = dict(BENCH_WORKLOADS[name])
    rows = []
    for mult in (1, factor):
        cfgs2 = dict(cfgs)
        cfgs2["t_max"] = cfgs["t_max"] * mult
        g = gen_temporal_graph(**cfgs2)
        k = default_k(name)
        tab, t_tab = timed(edge_core_times, g, k)
        pecb, t_p = timed(build_pecb_index, g, k, tab)
        ef, t_e = timed(EFIndex, g, k, tab)
        queries = random_queries(g, N_QUERIES // 2)
        rows.append([name, g.t_max, round(t_tab + t_p, 4), round(t_tab + t_e, 4),
                     pecb.nbytes(), ef.nbytes(),
                     round(_query_us(pecb, queries), 2),
                     round(_query_us(ef, queries), 2)])
    write_csv("fine_grained.csv",
              ["workload", "t_max", "pecb_s", "ef_s", "pecb_bytes", "ef_bytes",
               "pecb_us", "ef_us"], rows)
    return rows
