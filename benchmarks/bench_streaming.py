"""Streaming epoch plane benchmark (DESIGN.md §9, beyond paper).

Two scenarios on suffix appends:

* **Refresh vs cold rebuild** — split a workload at a late timestamp,
  build the epoch-0 index, append the suffix, then time the incremental
  refresh (``extend_core_times`` + ``extend_pecb_index`` +
  ``refresh_device``) against a full cold rebuild (``edge_core_times`` +
  ``build_pecb_index`` + ``to_device``) of the merged graph. **Equality is
  asserted before any number is reported** — every packed array of the
  refreshed index must be bit-identical to the cold build's; a speedup
  over a wrong index would be meaningless. On ``em_like`` the refresh is
  required (and asserted) to be >= 5x faster.

* **Query availability during refresh** — a serving engine ingests the
  suffix while a client hammers point queries; the bench records how many
  queries resolved *during* the background refresh window and their mean
  latency, demonstrating the old epoch keeps serving until the atomic
  handle swap (no downtime, no errors).

CSV: ``streaming.csv`` (one row per scenario) in results/bench/.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.batch_query import refresh_device, to_device
from repro.core.core_time import edge_core_times, extend_core_times
from repro.core.pecb_index import build_pecb_index
from repro.core.query_api import TCCSQuery
from repro.core.streaming import extend_pecb_index
from repro.core.temporal_graph import random_queries
from repro.serving import EngineConfig, ServingEngine

from .common import default_k, timed, workload, write_csv

PECB_FIELDS = ("node_u", "node_v", "node_ct", "node_edge", "node_live_from",
               "node_live_to", "row_ptr", "ent_ts", "ent_left", "ent_right",
               "ent_parent", "vrow_ptr", "vent_ts", "vent_node")

#: the acceptance floor asserted on em_like (the ISSUE's target workload)
MIN_EM_LIKE_SPEEDUP = 5.0


def _split(g, frac: float):
    t_old = max(1, int(g.t_max * frac))
    g0, suffix = g.split_at(t_old)
    return g0, [tuple(e) for e in suffix.tolist()]


def _assert_identical(a, b):
    for f in PECB_FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), \
            f"refresh diverged from cold rebuild on {f}"
    assert a.versions == b.versions, "version stores diverged"


#: k for the asserted em_like row: the forest-densest regime (most Python
#: insert work for the cold builder — the hardest cold rebuild the refresh
#: is compared against; higher k thins the forest and the cold build with it)
EM_LIKE_K = 5


def bench_refresh(workloads=("em_like",), frac: float = 0.98,
                  assert_speedup: bool = True, reps: int = 2):
    """rows: workload, k, suffix_edges, refresh stage seconds, cold
    seconds, speedup, bytes saved by the device-mirror refresh. Timings
    are best-of-``reps`` on both sides (this container's CPU clock is
    noisy; the floor assertion should compare steady-state costs)."""
    rows = []
    for name in workloads:
        g = workload(name)
        k = EM_LIKE_K if name == "em_like" else default_k(name)
        g0, suffix = _split(g, frac)
        tab0 = edge_core_times(g0, k)
        idx0 = build_pecb_index(g0, k, tab0)
        dix0 = to_device(idx0)
        g1 = g0.extend(suffix)

        best = None
        for _ in range(max(1, reps)):
            tab1, t_tab = timed(extend_core_times, g1, k, tab0)
            idx1, t_idx = timed(extend_pecb_index, g1, k, tab1, idx0)
            (dix1, upload), t_dev = timed(refresh_device, idx0, dix0, idx1)
            if best is None or t_tab + t_idx + t_dev < sum(best[:3]):
                best = (t_tab, t_idx, t_dev, tab1, idx1, upload)
        t_tab, t_idx, t_dev, tab1, idx1, upload = best
        refresh_s = t_tab + t_idx + t_dev

        cold_s = None
        for _ in range(max(1, reps)):
            tab_c, tc_tab = timed(edge_core_times, g, k)
            idx_c, tc_idx = timed(build_pecb_index, g, k, tab_c)
            _, tc_dev = timed(to_device, idx_c)
            cold_s = min(cold_s or 1e9, tc_tab + tc_idx + tc_dev)

        # exactness first, numbers second
        for f in ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct"):
            assert np.array_equal(getattr(tab1, f), getattr(tab_c, f)), f
        _assert_identical(idx1, idx_c)

        speedup = cold_s / refresh_s
        if assert_speedup and name == "em_like":
            assert speedup >= MIN_EM_LIKE_SPEEDUP, (
                f"em_like refresh speedup {speedup:.2f}x fell below the "
                f"{MIN_EM_LIKE_SPEEDUP}x acceptance floor")
        rows.append([name, k, len(suffix), round(t_tab, 4), round(t_idx, 4),
                     round(t_dev, 4), round(refresh_s, 4), round(cold_s, 4),
                     round(speedup, 2), upload["uploaded_bytes"],
                     upload["reused_bytes"]])
    write_csv("streaming.csv",
              ["workload", "k", "suffix_edges", "refresh_tab_s",
               "refresh_index_s", "refresh_device_s", "refresh_total_s",
               "cold_total_s", "speedup", "device_uploaded_bytes",
               "device_reused_bytes"],
              rows)
    return rows


def bench_availability(name: str = "em_like", frac: float = 0.98,
                       n_q: int = 512):
    """rows: queries answered during the background refresh + mean/worst
    latency, proving the old epoch serves with zero downtime."""
    g = workload(name)
    k = default_k(name)
    g0, suffix = _split(g, frac)
    rows = []
    with ServingEngine(EngineConfig(flush_ms=1.0)) as eng:
        eng.register_graph(name + "@stream", g0)
        eng.warmup(name + "@stream")
        qs = random_queries(g0, n_q, seed=7)
        # prime the serving path so in-refresh latencies measure steady
        # state, not the first request's batcher deadline
        eng.answer(name + "@stream", TCCSQuery(*qs[0], k))
        futures = eng.ingest(name + "@stream", suffix)
        refresh_fut = futures[name + "@stream"]
        lat, during = [], 0
        i = 0
        # always issue at least one query: on tiny smoke workloads the
        # refresh can land before the first client round trip, and "served
        # while/around the refresh" is still the property being measured
        while not refresh_fut.done() or during == 0:
            u, ts, te = qs[i % n_q]
            t0 = time.perf_counter()
            eng.answer(name + "@stream", TCCSQuery(u, ts, te, k))
            lat.append(time.perf_counter() - t0)
            during += 1
            i += 1
        handle = refresh_fut.result()
        refresh_s = handle.build_seconds
        rows.append([name, k, len(suffix), during, round(refresh_s, 4),
                     round(float(np.mean(lat)) * 1e3, 3),
                     round(float(np.max(lat)) * 1e3, 3)])
    write_csv("streaming_availability.csv",
              ["workload", "k", "suffix_edges", "queries_during_refresh",
               "refresh_s", "mean_ms", "worst_ms"],
              rows)
    return rows


if __name__ == "__main__":
    for r in bench_refresh():
        print(r)
    for r in bench_availability():
        print(r)
