"""Shared benchmark helpers: workloads, index builders, timing, CSV."""

from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro.core.temporal_graph import (BENCH_WORKLOADS, bench_graph,
                                       random_queries)
from repro.core.core_time import edge_core_times
from repro.core.pecb_index import build_pecb_index
from repro.core.ctmsf_index import CTMSFIndex
from repro.core.ef_index import EFIndex
from repro.core.kcore import k_max

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")

_KMAX_CACHE: dict = {}
_GRAPH_CACHE: dict = {}


def workload(name: str):
    if name not in _GRAPH_CACHE:
        _GRAPH_CACHE[name] = bench_graph(name)
    return _GRAPH_CACHE[name]


def default_k(name: str, frac: float = 0.7) -> int:
    if name not in _KMAX_CACHE:
        _KMAX_CACHE[name] = k_max(workload(name))
    return max(2, int(round(frac * _KMAX_CACHE[name])))


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def build_all(name: str, k: int):
    """(core-time table, pecb, ctmsf, ef) + build seconds for each."""
    g = workload(name)
    tab, t_tab = timed(edge_core_times, g, k)
    pecb, t_pecb = timed(build_pecb_index, g, k, tab)
    ctm, t_ctm = timed(CTMSFIndex, g, k, tab)
    ef, t_ef = timed(EFIndex, g, k, tab)
    times = {"core_times_s": t_tab, "pecb_s": t_tab + t_pecb,
             "ctmsf_s": t_tab + t_ctm, "ef_s": t_tab + t_ef}
    return g, tab, pecb, ctm, ef, times


def write_csv(name: str, header: list, rows: list):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path
