"""Persistent index store benchmark (DESIGN.md §13, beyond paper).

Two scenarios:

* **Warm restart vs cold build** — build a workload's index, write it
  through the :class:`repro.store.IndexStore`, then time a fresh store
  object (a "restarted process") mmap-loading + crc-verifying + device-
  uploading the stored epoch against the full cold build
  (``stratified_core_times`` + ``build_stratified_index`` +
  ``to_device``).
  **Equality is asserted before any number is reported** — every packed
  array, the version store, the core-time table and the graph arrays of
  the promoted index must be bit-identical to the cold build's. On
  ``em_like`` the warm restart is required (and asserted) to land in
  under :data:`MAX_WARM_RESTART_S` wall seconds and >=
  :data:`MIN_WARM_SPEEDUP` x faster than the cold build.

* **Delta vs full commit** — append a suffix epoch and compare the
  delta commit (reused + prefix/suffix parts only) against a full
  rewrite of the new epoch: bytes written and commit seconds.

CSV: ``store.csv`` / ``store_delta.csv`` in results/bench/.
"""

from __future__ import annotations

import shutil
import tempfile

import numpy as np

from repro.core.batch_query import to_device
from repro.core.core_time import (extend_stratified_core_times,
                                  stratified_core_times)
from repro.core.pecb_index import build_stratified_index
from repro.core.streaming import extend_stratified_index
from repro.serving.registry import IndexHandle
from repro.store import IndexStore

from .bench_streaming import PECB_FIELDS, _split
from .common import timed, workload, write_csv

#: the stratified table's stored arrays (per-k record blocks + RLE
#: vertex runs); the pre-PR-9 dense ``vertex_ct`` matrix is gone
TAB_FIELDS = ("kptr", "edge_id", "ts_from", "ts_to", "ct",
              "vptr", "v_ts_from", "v_ts_to", "v_ct")

#: k-stratified extras on top of the 14 shared packed arrays
STRAT_FIELDS = ("knode_ptr", "kent_ptr", "kvent_ptr",
                "ver_src", "ver_dst", "ver_t")

#: acceptance floors asserted on em_like (the ISSUE's target workload):
#: a warm restart must be sub-second and an order of magnitude cheaper
#: than rebuilding — otherwise the disk tier isn't paying for itself
MIN_WARM_SPEEDUP = 10.0
MAX_WARM_RESTART_S = 1.0


def _handle(name, g, tab, idx, dev, epoch=0):
    return IndexHandle(name, g, idx, dev, 0.0, epoch=epoch, tab=tab)


def _assert_promoted_identical(stored, g, tab, idx):
    for f in PECB_FIELDS + STRAT_FIELDS:
        assert np.array_equal(getattr(stored.pecb, f), getattr(idx, f)), \
            f"stored index diverged from cold build on {f}"
    assert stored.pecb.supported_ks == idx.supported_ks
    assert stored.pecb.versions == idx.versions, "version stores diverged"
    for f in TAB_FIELDS:
        assert np.array_equal(getattr(stored.tab, f), getattr(tab, f)), \
            f"stored core-time table diverged on {f}"
    for f in ("src", "dst", "t"):
        assert np.array_equal(getattr(stored.graph, f), getattr(g, f)), \
            f"stored graph diverged on {f}"


def bench_warm_restart(workloads=("em_like",), assert_speedup: bool = True,
                       reps: int = 2):
    """rows: workload, k, stored bytes, cold build seconds, warm open /
    device / total seconds, speedup. Timings are best-of-``reps`` on both
    sides. Every warm open is a *fresh* :class:`IndexStore` over the same
    root — the restarted-process path, crc verification included."""
    rows = []
    for name in workloads:
        g = workload(name)
        root = tempfile.mkdtemp(prefix="bench-store-")
        try:
            cold_s = None
            for _ in range(max(1, reps)):
                tab, t_tab = timed(stratified_core_times, g)
                idx, t_idx = timed(
                    lambda: build_stratified_index(g, strata=tab))
                dev, t_dev = timed(to_device, idx)
                cold_s = min(cold_s or 1e9, t_tab + t_idx + t_dev)
            res = IndexStore(root).put_handle(
                name, _handle(name, g, tab, idx, dev))
            assert res["mode"] == "full"

            best = None
            for _ in range(max(1, reps)):
                store = IndexStore(root)          # a restarted process
                stored, t_open = timed(store.load, name)
                _, t_up = timed(to_device, stored.pecb)
                if best is None or t_open + t_up < best[0] + best[1]:
                    best = (t_open, t_up, stored)
            t_open, t_up, stored = best
            warm_s = t_open + t_up

            # exactness first, numbers second
            _assert_promoted_identical(stored, g, tab, idx)

            speedup = cold_s / warm_s
            if assert_speedup and name == "em_like":
                assert warm_s <= MAX_WARM_RESTART_S, (
                    f"em_like warm restart took {warm_s:.3f}s, over the "
                    f"{MAX_WARM_RESTART_S}s acceptance ceiling")
                assert speedup >= MIN_WARM_SPEEDUP, (
                    f"em_like warm restart speedup {speedup:.2f}x fell "
                    f"below the {MIN_WARM_SPEEDUP}x acceptance floor")
            rows.append([name, len(idx.supported_ks), res["bytes_written"],
                         round(cold_s, 4),
                         round(t_open, 4), round(t_up, 4), round(warm_s, 4),
                         round(speedup, 2)])
        finally:
            shutil.rmtree(root, ignore_errors=True)
    write_csv("store.csv",
              ["workload", "n_ks", "stored_bytes", "cold_total_s",
               "warm_open_s", "warm_device_s", "warm_total_s", "speedup"],
              rows)
    return rows


def bench_delta(workloads=("em_like",), frac: float = 0.98):
    """rows: delta commit vs full rewrite of a suffix-extended epoch —
    bytes written and commit seconds for each, plus the bytes ratio."""
    rows = []
    for name in workloads:
        g = workload(name)
        g0, suffix = _split(g, frac)
        tab0 = stratified_core_times(g0)
        idx0 = build_stratified_index(g0, strata=tab0)
        dev0 = to_device(idx0)
        g1 = g0.extend(suffix)
        tab1 = extend_stratified_core_times(g1, tab0)
        idx1 = extend_stratified_index(g1, idx0, strata=tab1)
        h0 = _handle(name, g0, tab0, idx0, dev0)
        h1 = _handle(name, g1, tab1, idx1, dev0, epoch=1)

        root = tempfile.mkdtemp(prefix="bench-store-")
        try:
            store = IndexStore(root)
            store.put_handle(name, h0)
            delta, t_delta = timed(store.put_handle, name, h1, prev=h0)
            assert delta["mode"] == "delta", delta
        finally:
            shutil.rmtree(root, ignore_errors=True)
        root = tempfile.mkdtemp(prefix="bench-store-")
        try:
            full, t_full = timed(IndexStore(root).put_handle, name, h1)
            assert full["mode"] == "full"
        finally:
            shutil.rmtree(root, ignore_errors=True)
        rows.append([name, len(idx1.supported_ks), len(suffix),
                     full["bytes_written"], round(t_full, 4),
                     delta["bytes_written"], round(t_delta, 4),
                     round(delta["bytes_written"] / full["bytes_written"], 3)])
    write_csv("store_delta.csv",
              ["workload", "n_ks", "suffix_edges", "full_bytes", "full_s",
               "delta_bytes", "delta_s", "delta_bytes_ratio"],
              rows)
    return rows


if __name__ == "__main__":
    for r in bench_warm_restart():
        print(r)
    for r in bench_delta():
        print(r)
