"""Sliding-window retention plane benchmark (DESIGN.md §10, beyond paper).

Two scenarios on prefix expiry:

* **Shrink vs cold rebuild** — build a workload's index, expire the first
  half of the timeline (``TemporalGraph.expire_before``), then time the
  incremental shrink (``shrink_core_times`` + ``shrink_pecb_index`` +
  ``refresh_device``) against a full cold rebuild (``edge_core_times`` +
  ``build_pecb_index`` + ``to_device``) of the truncated edge list.
  **Equality is asserted before any number is reported** — every packed
  array of the shrunk index must be bit-identical to the cold build's; a
  speedup over a wrong index would be meaningless. On ``em_like`` the
  shrink is required (and asserted) to be >= 3x faster.

* **Rolling window** — the sliding-window steady state the retention
  plane exists for: a serving engine under a ``RetentionPolicy`` ingests
  append chunks while auto-trims expire the prefix, for >= 5 full
  append+expire cycles. Per cycle the bench records the resident index
  bytes, retained-table bytes and ``t_max``; it **asserts** that the
  post-trim timeline never exceeds ``window + slack``, that steady-state
  index ``nbytes`` stays bounded (max/min across cycles within 2x — no
  monotone growth), and that the final trimmed index is smaller than a
  cold index over the full untrimmed stream (the memory a non-retaining
  deployment would have accreted).

CSVs: ``retention.csv`` / ``retention_rolling.csv`` in results/bench/.
"""

from __future__ import annotations

import numpy as np

from repro.core.batch_query import refresh_device, to_device
from repro.core.core_time import edge_core_times, shrink_core_times
from repro.core.pecb_index import build_pecb_index, build_stratified_index
from repro.core.streaming import shrink_pecb_index
from repro.core.temporal_graph import gen_temporal_graph
from repro.serving import EngineConfig, RetentionPolicy, ServingEngine

from .common import default_k, timed, workload, write_csv

PECB_FIELDS = ("node_u", "node_v", "node_ct", "node_edge", "node_live_from",
               "node_live_to", "row_ptr", "ent_ts", "ent_left", "ent_right",
               "ent_parent", "vrow_ptr", "vent_ts", "vent_node")

#: the acceptance floor asserted on em_like (the ISSUE's target workload)
MIN_EM_LIKE_SPEEDUP = 3.0

#: k for the asserted em_like row — the forest-densest regime, matching
#: bench_streaming: the hardest cold rebuild the shrink is compared against
EM_LIKE_K = 5


def _assert_identical(a, b):
    for f in PECB_FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), \
            f"shrink diverged from cold rebuild on {f}"
    assert a.versions == b.versions, "version stores diverged"


def bench_shrink(workloads=("em_like",), frac: float = 0.5,
                 assert_speedup: bool = True, reps: int = 2):
    """rows: workload, k, cut point, expired edges, shrink stage seconds,
    cold seconds, speedup, device bytes freed by the swap. Timings are
    best-of-``reps`` on both sides (noisy container CPU clock)."""
    rows = []
    for name in workloads:
        g = workload(name)
        k = EM_LIKE_K if name == "em_like" else default_k(name)
        t_cut = max(2, int(g.t_max * frac))
        tab0 = edge_core_times(g, k)
        idx0 = build_pecb_index(g, k, tab0)
        dix0 = to_device(idx0)
        g2 = g.expire_before(t_cut)

        best = None
        for _ in range(max(1, reps)):
            tab2, t_tab = timed(shrink_core_times, g2, k, tab0)
            idx2, t_idx = timed(shrink_pecb_index, g2, k, tab2, idx0)
            (dix2, upload), t_dev = timed(refresh_device, idx0, dix0, idx2)
            if best is None or t_tab + t_idx + t_dev < sum(best[:3]):
                best = (t_tab, t_idx, t_dev, tab2, idx2, upload)
        t_tab, t_idx, t_dev, tab2, idx2, upload = best
        shrink_s = t_tab + t_idx + t_dev

        cold_s = None
        for _ in range(max(1, reps)):
            tab_c, tc_tab = timed(edge_core_times, g2, k)
            idx_c, tc_idx = timed(build_pecb_index, g2, k, tab_c)
            _, tc_dev = timed(to_device, idx_c)
            cold_s = min(cold_s or 1e9, tc_tab + tc_idx + tc_dev)

        # exactness first, numbers second
        for f in ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct"):
            assert np.array_equal(getattr(tab2, f), getattr(tab_c, f)), f
        _assert_identical(idx2, idx_c)

        speedup = cold_s / shrink_s
        if assert_speedup and name == "em_like":
            assert speedup >= MIN_EM_LIKE_SPEEDUP, (
                f"em_like shrink speedup {speedup:.2f}x fell below the "
                f"{MIN_EM_LIKE_SPEEDUP}x acceptance floor")
        rows.append([name, k, t_cut, g.m - g2.m, round(t_tab, 4),
                     round(t_idx, 4), round(t_dev, 4), round(shrink_s, 4),
                     round(cold_s, 4), round(speedup, 2),
                     upload["freed_bytes"]])
    write_csv("retention.csv",
              ["workload", "k", "t_cut", "expired_edges", "shrink_tab_s",
               "shrink_index_s", "shrink_device_s", "shrink_total_s",
               "cold_total_s", "speedup", "device_freed_bytes"],
              rows)
    return rows


def bench_rolling(name: str = "em_like", cycles: int = 5):
    """rows: one per append+expire cycle — t_max after trim, resident index
    bytes, retained-table bytes, trim seconds. Asserts the bounded-memory
    steady state (see module doc) before returning."""
    base = workload(name)
    # dense-forest regime (matching the shrink row): near k_max the forest
    # is sparse and its size volatile across windows, which would turn the
    # steady-state bound into a content lottery
    k = EM_LIKE_K if name == "em_like" else max(2, min(5, default_k(name)))
    # a stream twice the workload's horizon, same shape: the first half
    # seeds the engine, the second streams in as append chunks
    cfg = dict(n=base.n, m=2 * base.m, t_max=2 * base.t_max, seed=1234)
    stream = gen_temporal_graph(**cfg)
    window = base.t_max // 2
    slack = max(1, window // 8)
    chunk_ts = max(1, (stream.t_max - window) // cycles)

    rows = []
    nbytes_post, tmax_post = [], []
    with ServingEngine(EngineConfig(flush_ms=1.0)) as eng:
        g0, _ = stream.split_at(window)
        eng.register_graph(name + "@roll", g0)
        eng.registry.get(name + "@roll")
        eng.set_retention(name + "@roll", RetentionPolicy(window=window,
                                                          slack=slack))
        offset = 0           # absolute stream time minus engine time
        t_abs = window
        for cycle in range(1, cycles + 1):
            t_hi = min(t_abs + chunk_ts, stream.t_max)
            lo = int(np.searchsorted(stream.t, t_abs, side="right"))
            hi = int(np.searchsorted(stream.t, t_hi, side="right"))
            chunk = [(int(u), int(v), int(t) - offset)
                     for u, v, t in zip(stream.src[lo:hi], stream.dst[lo:hi],
                                        stream.t[lo:hi])]
            futs = eng.ingest(name + "@roll", chunk, wait=True)
            t_abs = t_hi
            h = eng.registry.get_nowait(name + "@roll", start_build=False)
            offset = t_abs - h.graph.t_max
            landed = [f.result() for f in futs.values()]
            trim_s = max((h2.build_seconds for h2 in landed
                          if h2 is not None), default=0.0)
            nbytes_post.append(h.nbytes)
            tmax_post.append(h.graph.t_max)
            rows.append([name, k, window, cycle, h.graph.t_max, h.nbytes,
                         h.tab_nbytes, len(eng.cache), round(trim_s, 4)])

        # bounded-memory assertions: exactness of every swapped index is
        # already covered by the shrink/grow equality tests and benches
        assert all(t <= window + slack for t in tmax_post), tmax_post
        # the RLE vertex strata — the dominant retained-memory term —
        # are deterministically bounded by the retained timeline: at most
        # one run boundary per (stratum, vertex, retained timestamp)
        assert h.tab.num_versions <= \
            len(h.tab.ks) * base.n * (window + slack + 1)
        assert max(nbytes_post) <= 2.0 * min(nbytes_post), nbytes_post
        # control on the SAME plane as the resident handle: a k-stratified
        # build (default ks policy) over the full untrimmed stream — what a
        # non-retaining deployment would keep resident
        untrimmed = build_stratified_index(
            stream.split_at(t_abs)[0]).nbytes()
        assert nbytes_post[-1] < untrimmed, (nbytes_post[-1], untrimmed)
        rows.append([name, k, window, "untrimmed-control", t_abs, untrimmed,
                     "", "", ""])
    write_csv("retention_rolling.csv",
              ["workload", "k", "window", "cycle", "t_max", "index_bytes",
               "tab_bytes", "cache_entries", "trim_s"],
              rows)
    return rows


if __name__ == "__main__":
    for r in bench_shrink():
        print(r)
    for r in bench_rolling():
        print(r)
