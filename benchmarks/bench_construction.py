"""Construction-plane benchmark: PR-1 baseline vs the batched engines.

The paper's headline claim is construction cost (ECB builds up to 100x
faster than EF); this bench tracks *our own* construction trajectory across
PRs. Both planes are measured cold in the same run so the speedup column is
self-contained:

* ``pr1`` — the seed path: per-start-time projection + lexsort fixpoint
  (``edge_core_times(engine="legacy")``) and the per-version Python insert
  loop (``IncrementalBuilder(prefilter=False)``).
* ``batched`` — the PR-2 plane: precomputed pair-CSR/t_uv sweep engine
  (host or jitted JAX, ``engine="auto"``), MSF-prefiltered builder, and the
  lexsort ``pack_index``.

The two planes are asserted to produce identical ``CoreTimeTable``s (all
five arrays) and identical packed indexes before any number is reported —
a benchmark of a wrong answer is worthless.

CSV: ``construction_plane.csv``.
"""

from __future__ import annotations

import numpy as np

from repro.core.core_time import edge_core_times, stratified_core_times
from repro.core.ecb_forest import IncrementalBuilder
from repro.core.pecb_index import (build_pecb_index, build_stratified_index,
                                   pack_index)

from .common import default_k, timed, workload, write_csv

WORKLOADS = ["fb_like", "cm_like", "em_like", "mo_like", "wk_like"]

_TABLE_FIELDS = ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct")

#: acceptance floors for the |K|-stratified scenario on em_like (the
#: ISSUE's target workload): one stratified build must beat |K| per-k
#: builds by >= 3x cold and hold registry+store bytes >= 2x smaller
MIN_STRATIFIED_SPEEDUP = 3.0
MIN_STRATIFIED_BYTES_RATIO = 2.0

_VERSION_ARRAYS = ("edge_id", "ts_from", "ts_to", "ct", "src", "dst", "t")


def _assert_identical(name, tab_old, tab_new, idx_old, idx_new):
    for f in _TABLE_FIELDS:
        if not np.array_equal(getattr(tab_old, f), getattr(tab_new, f)):
            raise AssertionError(f"{name}: CoreTimeTable.{f} differs between "
                                 "the legacy and batched construction planes")
    import dataclasses
    for f in dataclasses.fields(idx_old):
        va, vb = getattr(idx_old, f.name), getattr(idx_new, f.name)
        same = np.array_equal(va, vb) if isinstance(va, np.ndarray) else va == vb
        if not same:
            raise AssertionError(f"{name}: PECBIndex.{f.name} differs between "
                                 "the two construction planes")


def bench_construction_plane(workloads=WORKLOADS):
    rows = []
    for name in workloads:
        k = default_k(name)
        g = workload(name)
        # -- PR-1 baseline (cold, measured first) -----------------------
        tab_old, t_core_old = timed(edge_core_times, g, k, engine="legacy")
        b_old, t_forest_old = timed(
            lambda: IncrementalBuilder(g, tab_old, prefilter=False).run())
        idx_old, t_pack_old = timed(pack_index, g, k, b_old)
        old_s = t_core_old + t_forest_old + t_pack_old
        # -- batched plane (cold: includes any jit compile) -------------
        tab_new, t_core_new = timed(edge_core_times, g, k)
        b_new, t_forest_new = timed(
            lambda: IncrementalBuilder(g, tab_new).run())
        idx_new, t_pack_new = timed(pack_index, g, k, b_new)
        new_s = t_core_new + t_forest_new + t_pack_new
        _assert_identical(name, tab_old, tab_new, idx_old, idx_new)
        rows.append([
            name, k,
            round(t_core_old, 4), round(t_forest_old + t_pack_old, 4),
            round(old_s, 4),
            round(t_core_new, 4), round(t_forest_new + t_pack_new, 4),
            round(new_s, 4),
            round(old_s / new_s, 2),
        ])
    write_csv("construction_plane.csv",
              ["workload", "k", "pr1_core_s", "pr1_forest_s", "pr1_total_s",
               "batched_core_s", "batched_forest_s", "batched_total_s",
               "speedup"], rows)
    return rows


def _per_k_plane_bytes(g, tabs, idxs):
    """Registry + store footprint of the pre-PR-9 per-k plane, measured
    on real per-k builds. Registry: each handle kept its packed index,
    its core-time records, the dense ``(t_max+1, n)`` vertex matrix and
    an eagerly-built version store. Store: the PR-8 layout wrote all of
    those arrays — graph included — once per ``(workload, k)`` key."""
    graph_b = int(g.src.nbytes + g.dst.nbytes + g.t.nbytes)
    reg = store = 0
    for tab, idx in zip(tabs, idxs):
        ver_b = sum(int(getattr(idx.versions, f).nbytes)
                    for f in _VERSION_ARRAYS)
        handle_b = (idx.nbytes() + tab.nbytes()
                    + int(tab.vertex_ct.nbytes) + ver_b)
        reg += handle_b
        store += handle_b + graph_b
    return reg, store


def _stratified_plane_bytes(g, stab, sx):
    """Registry + store footprint of the one-build plane: what the
    registry's ``resident_bytes``/``resident_tab_bytes`` stats report for
    the single handle (version arrays are derived lazily, not retained),
    plus the actual bytes a fresh :class:`IndexStore` commit writes."""
    import shutil
    import tempfile

    from repro.core.batch_query import to_device
    from repro.serving.registry import IndexHandle
    from repro.store import IndexStore

    reg = sx.nbytes() + stab.nbytes()
    root = tempfile.mkdtemp(prefix="bench-strat-")
    try:
        h = IndexHandle("strat", g, sx, to_device(sx), 0.0, tab=stab)
        store = IndexStore(root).put_handle("strat", h)["bytes_written"]
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return reg, int(store)


def bench_stratified_construction(name: str = "em_like", n_ks: int = 8,
                                  assert_floors: bool = True):
    """|K|-stratified scenario (PR-9 tentpole): ONE k-stratified build vs
    |K| separate per-k builds of the same strata.

    Every stratum of the stratified index is asserted bit-identical to
    its per-k build before any number is reported. Floors (em_like only):
    cold build >= 3x faster, registry+store bytes >= 2x smaller.

    CSV row: workload, |K|, ks, per-k build s, stratified build s,
    speedup, per-k registry+store MB, stratified registry+store MB,
    bytes ratio.
    """
    from repro.core.kcore import k_max

    g = workload(name)
    km = k_max(g)
    ks = tuple(range(2, 2 + min(n_ks, km - 1)))

    per_k_s = 0.0
    tabs, idxs = [], []
    for k in ks:
        tab, t_tab = timed(edge_core_times, g, k)
        idx, t_idx = timed(build_pecb_index, g, k, tab)
        per_k_s += t_tab + t_idx
        tabs.append(tab)
        idxs.append(idx)

    stab, t_stab = timed(stratified_core_times, g, ks)
    sx, t_sx = timed(build_stratified_index, g, ks, strata=stab)
    strat_s = t_stab + t_sx

    # exactness first, numbers second: every stratum bit-identical
    import dataclasses
    for k, idx in zip(ks, idxs):
        sl = sx.slice_k(k)
        for f in dataclasses.fields(idx):
            va, vb = getattr(idx, f.name), getattr(sl, f.name)
            if isinstance(va, np.ndarray):
                assert np.array_equal(va, vb), (
                    f"{name}: stratum k={k} field {f.name} diverged from "
                    "the per-k build")

    perk_reg, perk_store = _per_k_plane_bytes(g, tabs, idxs)
    strat_reg, strat_store = _stratified_plane_bytes(g, stab, sx)
    perk_b = perk_reg + perk_store
    strat_b = strat_reg + strat_store

    speedup = per_k_s / strat_s
    bytes_ratio = perk_b / strat_b
    if assert_floors and name == "em_like":
        assert speedup >= MIN_STRATIFIED_SPEEDUP, (
            f"em_like |K|={len(ks)} stratified build speedup "
            f"{speedup:.2f}x fell below the {MIN_STRATIFIED_SPEEDUP}x "
            "acceptance floor")
        assert bytes_ratio >= MIN_STRATIFIED_BYTES_RATIO, (
            f"em_like |K|={len(ks)} registry+store bytes ratio "
            f"{bytes_ratio:.2f}x fell below the "
            f"{MIN_STRATIFIED_BYTES_RATIO}x acceptance floor")

    rows = [[name, len(ks), f"{ks[0]}-{ks[-1]}",
             round(per_k_s, 4), round(strat_s, 4), round(speedup, 2),
             round(perk_b / 1e6, 2), round(strat_b / 1e6, 2),
             round(bytes_ratio, 2)]]
    write_csv("construction_stratified.csv",
              ["workload", "n_ks", "ks", "perk_build_s", "strat_build_s",
               "build_speedup", "perk_mb", "strat_mb", "bytes_ratio"],
              rows)
    return rows
