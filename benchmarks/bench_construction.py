"""Construction-plane benchmark: PR-1 baseline vs the batched engines.

The paper's headline claim is construction cost (ECB builds up to 100x
faster than EF); this bench tracks *our own* construction trajectory across
PRs. Both planes are measured cold in the same run so the speedup column is
self-contained:

* ``pr1`` — the seed path: per-start-time projection + lexsort fixpoint
  (``edge_core_times(engine="legacy")``) and the per-version Python insert
  loop (``IncrementalBuilder(prefilter=False)``).
* ``batched`` — the PR-2 plane: precomputed pair-CSR/t_uv sweep engine
  (host or jitted JAX, ``engine="auto"``), MSF-prefiltered builder, and the
  lexsort ``pack_index``.

The two planes are asserted to produce identical ``CoreTimeTable``s (all
five arrays) and identical packed indexes before any number is reported —
a benchmark of a wrong answer is worthless.

CSV: ``construction_plane.csv``.
"""

from __future__ import annotations

import numpy as np

from repro.core.core_time import edge_core_times
from repro.core.ecb_forest import IncrementalBuilder
from repro.core.pecb_index import pack_index

from .common import default_k, timed, workload, write_csv

WORKLOADS = ["fb_like", "cm_like", "em_like", "mo_like", "wk_like"]

_TABLE_FIELDS = ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct")


def _assert_identical(name, tab_old, tab_new, idx_old, idx_new):
    for f in _TABLE_FIELDS:
        if not np.array_equal(getattr(tab_old, f), getattr(tab_new, f)):
            raise AssertionError(f"{name}: CoreTimeTable.{f} differs between "
                                 "the legacy and batched construction planes")
    import dataclasses
    for f in dataclasses.fields(idx_old):
        va, vb = getattr(idx_old, f.name), getattr(idx_new, f.name)
        same = np.array_equal(va, vb) if isinstance(va, np.ndarray) else va == vb
        if not same:
            raise AssertionError(f"{name}: PECBIndex.{f.name} differs between "
                                 "the two construction planes")


def bench_construction_plane(workloads=WORKLOADS):
    rows = []
    for name in workloads:
        k = default_k(name)
        g = workload(name)
        # -- PR-1 baseline (cold, measured first) -----------------------
        tab_old, t_core_old = timed(edge_core_times, g, k, engine="legacy")
        b_old, t_forest_old = timed(
            lambda: IncrementalBuilder(g, tab_old, prefilter=False).run())
        idx_old, t_pack_old = timed(pack_index, g, k, b_old)
        old_s = t_core_old + t_forest_old + t_pack_old
        # -- batched plane (cold: includes any jit compile) -------------
        tab_new, t_core_new = timed(edge_core_times, g, k)
        b_new, t_forest_new = timed(
            lambda: IncrementalBuilder(g, tab_new).run())
        idx_new, t_pack_new = timed(pack_index, g, k, b_new)
        new_s = t_core_new + t_forest_new + t_pack_new
        _assert_identical(name, tab_old, tab_new, idx_old, idx_new)
        rows.append([
            name, k,
            round(t_core_old, 4), round(t_forest_old + t_pack_old, 4),
            round(old_s, 4),
            round(t_core_new, 4), round(t_forest_new + t_pack_new, 4),
            round(new_s, 4),
            round(old_s / new_s, 2),
        ])
    write_csv("construction_plane.csv",
              ["workload", "k", "pr1_core_s", "pr1_forest_s", "pr1_total_s",
               "batched_core_s", "batched_forest_s", "batched_total_s",
               "speedup"], rows)
    return rows
