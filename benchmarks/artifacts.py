"""Perf-trajectory artifacts: ``BENCH_<area>.json`` (DESIGN.md §11.7).

Every ``benchmarks.run`` invocation distills each bench area's raw rows
into one small, schema-stable JSON document that is committed alongside
the code it measured. The point is the *trajectory*: two checkouts'
``BENCH_engine.json`` diff cleanly, and a regression shows up in review
as a changed number, not a vanished stdout line.

Raw wall times are machine-dependent, so every document embeds a
calibration factor: the best-of-N wall time of a fixed, seeded numpy
workload (``calibrate``). Time metrics also carry
``normalized = seconds / calib_s`` and throughput metrics
``normalized = qps * calib_s`` — dimensionless "how many calibration
units does this cost/deliver" numbers that are comparable across hosts
to first order (same caveats as any single-number machine score).

Schema (``SCHEMA_VERSION = 1``)::

    {
      "schema_version": 1,
      "area": "engine",                  # one of AREAS
      "fast": false,                     # --fast (CI smoke) run?
      "machine": {"platform": ..., "cpu_count": ..., "python": ...,
                  "jax": ..., "numpy": ..., "calib_s": ...},
      "metrics": {name: {"value": v, "unit": u, "normalized": n|null}},
      "tables":  {title: {"header": [...], "rows": [[...], ...]}}
    }

``validate_bench_artifact`` is the gate the test suite and the CI bench
smoke run over every produced file.
"""

from __future__ import annotations

import json
import os
import platform
import sys
import time

import numpy as np

SCHEMA_VERSION = 1
AREAS = ("construction", "engine", "streaming", "retention", "sweep", "store")

#: units carrying a time dimension (normalized by dividing by calib_s)
#: and their scale to seconds
_TIME_UNITS = {"s": 1.0, "ms": 1e-3, "us": 1e-6}


def calibrate(reps: int = 3) -> float:
    """Best-of-``reps`` seconds for a fixed, seeded numpy workload —
    the document's machine-speed yardstick. Deliberately mixed (matmul +
    norm + reduction) so it tracks general FP throughput rather than one
    BLAS corner; small enough to cost ~100ms on a laptop."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((384, 384))
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        b = a
        for _ in range(8):
            b = b @ a
            b = b / np.linalg.norm(b)
        float(b.sum())
        best = min(best, time.perf_counter() - t0)
    return best


def machine_info(calib_s: float | None = None) -> dict:
    import jax
    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count() or 1,
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "numpy": np.__version__,
        "jax_devices": len(jax.devices()),
        "calib_s": round(calibrate() if calib_s is None else calib_s, 6),
    }


def normalized(value: float, unit: str, calib_s: float):
    """Machine-normalized form of a metric, or None for units that carry
    no time dimension (bytes, counts, dimensionless ratios)."""
    if unit in _TIME_UNITS:
        return round(value * _TIME_UNITS[unit] / calib_s, 6)
    if unit == "qps":
        return round(value * calib_s, 6)
    return None


def bench_artifact(area: str, metrics: dict, tables: dict | None = None,
                   machine: dict | None = None, fast: bool = False) -> dict:
    """Build one area's document. ``metrics`` maps name -> (value, unit);
    ``tables`` maps title -> (header, rows) for the raw bench rows."""
    assert area in AREAS, area
    machine = machine if machine is not None else machine_info()
    calib_s = machine["calib_s"]
    doc = {
        "schema_version": SCHEMA_VERSION,
        "area": area,
        "fast": bool(fast),
        "machine": machine,
        "metrics": {
            name: {"value": _num(value), "unit": unit,
                   "normalized": normalized(float(value), unit, calib_s)}
            for name, (value, unit) in metrics.items()
        },
        "tables": {
            title: {"header": list(header),
                    "rows": [[_num(x) for x in row] for row in rows]}
            for title, (header, rows) in (tables or {}).items()
        },
    }
    validate_bench_artifact(doc)
    return doc


def _num(x):
    """Scalars only — numpy collapses to python, floats round for diff
    stability, everything else must already be str/int/bool."""
    item = getattr(x, "item", None)
    if callable(item):
        x = x.item()
    if isinstance(x, float):
        return round(x, 6)
    if isinstance(x, (int, str, bool)) or x is None:
        return x
    raise TypeError(f"non-scalar bench value {x!r}")


def write_bench_json(out_dir: str, area: str, metrics: dict,
                     tables: dict | None = None, machine: dict | None = None,
                     fast: bool = False) -> str:
    doc = bench_artifact(area, metrics, tables, machine, fast)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{area}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True, allow_nan=False)
        f.write("\n")
    return path


def validate_bench_artifact(doc) -> None:
    """Schema gate; raises ``ValueError`` on the first violation."""
    if not isinstance(doc, dict):
        raise ValueError("bench artifact must be a JSON object")
    if doc.get("schema_version") != SCHEMA_VERSION:
        raise ValueError(f"schema_version must be {SCHEMA_VERSION}, "
                         f"got {doc.get('schema_version')!r}")
    if doc.get("area") not in AREAS:
        raise ValueError(f"area must be one of {AREAS}, got {doc.get('area')!r}")
    if not isinstance(doc.get("fast"), bool):
        raise ValueError("'fast' must be a bool")
    machine = doc.get("machine")
    if not isinstance(machine, dict):
        raise ValueError("'machine' must be an object")
    calib = machine.get("calib_s")
    if not isinstance(calib, (int, float)) or calib <= 0:
        raise ValueError("machine.calib_s must be a positive number")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        raise ValueError("'metrics' must be a non-empty object")
    for name, m in metrics.items():
        if not isinstance(m, dict):
            raise ValueError(f"metric {name!r} is not an object")
        if not isinstance(m.get("value"), (int, float, str, bool)):
            raise ValueError(f"metric {name!r} missing scalar 'value'")
        if not isinstance(m.get("unit"), str):
            raise ValueError(f"metric {name!r} missing string 'unit'")
        norm = m.get("normalized")
        if norm is not None and not isinstance(norm, (int, float)):
            raise ValueError(f"metric {name!r} 'normalized' must be a "
                             "number or null")
    tables = doc.get("tables", {})
    if not isinstance(tables, dict):
        raise ValueError("'tables' must be an object")
    for title, t in tables.items():
        if (not isinstance(t, dict) or not isinstance(t.get("header"), list)
                or not isinstance(t.get("rows"), list)):
            raise ValueError(f"table {title!r} needs 'header' and 'rows' lists")
        width = len(t["header"])
        for row in t["rows"]:
            if not isinstance(row, list) or len(row) != width:
                raise ValueError(f"table {title!r} has a row not matching "
                                 f"its {width}-column header")
    # round-trippable end to end (numpy scalars would die here, not in CI)
    json.loads(json.dumps(doc, allow_nan=False))


def load_bench_json(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    validate_bench_artifact(doc)
    return doc


def validate_bench_files(dirpath: str,
                         require: tuple = AREAS) -> dict:
    """Load + validate every ``BENCH_<area>.json`` under ``dirpath``;
    raises if a required area's file is missing or invalid. Returns
    {area: document}."""
    docs = {}
    for area in AREAS:
        path = os.path.join(dirpath, f"BENCH_{area}.json")
        if not os.path.exists(path):
            if area in require:
                raise FileNotFoundError(f"missing bench artifact {path}")
            continue
        docs[area] = load_bench_json(path)
    return docs
